//! The pluggable compute backend: model forward/backward and quantizer-kernel
//! execution behind one object-safe trait.
//!
//! The quantizer/solver math (L3) is backend-agnostic; what differs is where
//! gradients come from and where the L1 quantizer kernels run:
//!
//! * [`NativeBackend`](super::NativeBackend) — pure Rust, zero dependencies,
//!   the default. Linear/MLP and bigram-LM fwd/bwd plus the scalar kernels in
//!   [`quant::kernels`](crate::quant::kernels).
//! * `PjrtBackend` (cargo feature `pjrt`) — AOT-compiled JAX/Pallas HLO
//!   executed through PJRT, loaded from `artifacts/manifest.json`.
//!
//! The [`Coordinator`](crate::coordinator::Coordinator) and
//! [`Trainer`](crate::train::Trainer) only ever see `&dyn Backend`, so new
//! backends (GPU, remote executor, ...) slot in without touching the
//! distributed runtime.

use anyhow::{bail, Result};

use super::manifest::ModelSpec;
use super::native::NativeBackend;
use crate::config::ExperimentConfig;

/// Output of one gradient computation: batch-mean loss + flat gradient.
#[derive(Clone, Debug)]
pub struct GradResult {
    /// Mean training loss over the batch.
    pub loss: f32,
    /// Gradient of the mean loss w.r.t. the flat parameter vector.
    pub grads: Vec<f32>,
}

/// Output of one evaluation batch (sums, so chunks can be accumulated).
#[derive(Clone, Copy, Debug)]
pub struct EvalResult {
    /// Sum of per-sample losses (classifier) or per-token NLLs (LM).
    pub loss_sum: f64,
    /// Number of correct predictions (classifier) or tokens scored (LM).
    pub count: f64,
}

/// A compute backend: owns the models it can run and executes fwd/bwd and
/// quantizer kernels for the coordinator.
///
/// Buffer conventions match the AOT artifact signatures: inputs and outputs
/// are flat `f32` slices. Classifier models take `x = [B * input_dim]`
/// pixels and `y = [B]` labels; LM models take `x = [B * (seq_len + 1)]`
/// tokens and an empty `y`.
pub trait Backend {
    /// Human-readable backend identifier (e.g. `"native"`, `"pjrt (cpu)"`).
    fn name(&self) -> String;

    /// Names of the models this backend can run.
    fn models(&self) -> Vec<String>;

    /// Metadata for one model (parameter count, layer groups, batch sizes).
    fn model(&self, name: &str) -> Result<ModelSpec>;

    /// Deterministic initial flat parameter vector for a model.
    fn init_params(&self, model: &str) -> Result<Vec<f32>>;

    /// Batch-mean loss and gradient at `params` for one training batch.
    fn grad(&self, model: &str, params: &[f32], x: &[f32], y: &[f32]) -> Result<GradResult>;

    /// Evaluation sums at `params` for one held-out batch.
    fn eval(&self, model: &str, params: &[f32], x: &[f32], y: &[f32]) -> Result<EvalResult>;

    /// Quantizer-kernel executor for a manifest entry name such as
    /// `"quant_uniform_b3"`, `"quant_nonuniform_b3"`, `"quant_biscaled_b3"`
    /// or `"tail_stats"` — the L1↔L3 parity surface.
    fn quant_kernel(&self, entry: &str) -> Result<Box<dyn QuantKernel>>;
}

/// Executor for the standalone quantizer kernels (the L1 surface).
///
/// `g` is the gradient tile, `u` the per-element uniforms driving stochastic
/// rounding; both must have equal length. Implementations built on fixed-tile
/// artifacts additionally require `g.len() == tile()`.
pub trait QuantKernel {
    /// Preferred tile length (fixed for AOT artifacts, advisory for native).
    fn tile(&self) -> usize;

    /// Truncated uniform quantizer: returns (dequantized values, indices).
    fn run_uniform(&self, g: &[f32], u: &[f32], alpha: f32) -> Result<(Vec<f32>, Vec<u32>)>;

    /// [`Self::run_uniform`] writing into caller-provided buffers (cleared
    /// first) — the L1 mirror of the codec layer's `*_into` discipline.
    /// Backends that compute natively override this to skip the staging
    /// allocations; the default delegates to the allocating path.
    fn run_uniform_into(
        &self,
        g: &[f32],
        u: &[f32],
        alpha: f32,
        deq: &mut Vec<f32>,
        idx: &mut Vec<u32>,
    ) -> Result<()> {
        let (d, i) = self.run_uniform(g, u, alpha)?;
        deq.clear();
        deq.extend_from_slice(&d);
        idx.clear();
        idx.extend_from_slice(&i);
        Ok(())
    }

    /// Codebook quantizer: `codebook` is strictly increasing with s+1 levels.
    fn run_codebook(&self, g: &[f32], u: &[f32], codebook: &[f32])
        -> Result<(Vec<f32>, Vec<u32>)>;

    /// [`Self::run_codebook`] writing into caller-provided buffers (cleared
    /// first); same contract as [`Self::run_uniform_into`].
    fn run_codebook_into(
        &self,
        g: &[f32],
        u: &[f32],
        codebook: &[f32],
        deq: &mut Vec<f32>,
        idx: &mut Vec<u32>,
    ) -> Result<()> {
        let (d, i) = self.run_codebook(g, u, codebook)?;
        deq.clear();
        deq.extend_from_slice(&d);
        idx.clear();
        idx.extend_from_slice(&i);
        Ok(())
    }

    /// BiScaled quantizer with outer threshold `alpha`, inner `beta`.
    fn run_biscaled(
        &self,
        g: &[f32],
        u: &[f32],
        alpha: f32,
        beta: f32,
    ) -> Result<(Vec<f32>, Vec<u32>)>;

    /// Tail statistics: `[n_tail, sum_log, sum_abs, sum_sq, abs_max]`.
    fn run_stats(&self, g: &[f32], g_min: f32) -> Result<Vec<f32>>;
}

/// Build the backend an experiment asks for (`cfg.backend`).
pub fn make_backend(cfg: &ExperimentConfig) -> Result<Box<dyn Backend>> {
    backend_for(&cfg.backend, &cfg.artifacts_dir)
}

/// Build a backend by kind: `"native"`, `"pjrt"`, or `"auto"`.
///
/// `"auto"` selects PJRT when the crate was built with the `pjrt` feature AND
/// `artifacts_dir/manifest.json` exists, falling back to the native backend —
/// so a clean checkout with no Python/JAX toolchain always runs.
pub fn backend_for(kind: &str, artifacts_dir: &str) -> Result<Box<dyn Backend>> {
    match kind {
        "native" => Ok(Box::new(NativeBackend::new())),
        "pjrt" => pjrt_backend(artifacts_dir),
        "auto" => {
            if cfg!(feature = "pjrt")
                && std::path::Path::new(artifacts_dir).join("manifest.json").exists()
            {
                return pjrt_backend(artifacts_dir);
            }
            Ok(Box::new(NativeBackend::new()))
        }
        other => bail!("unknown backend {other:?}; expected auto | native | pjrt"),
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_backend(artifacts_dir: &str) -> Result<Box<dyn Backend>> {
    Ok(Box::new(super::pjrt::PjrtBackend::open(artifacts_dir)?))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend(_artifacts_dir: &str) -> Result<Box<dyn Backend>> {
    bail!("this build has no PJRT support; rebuild with `--features pjrt` or use --backend native")
}
