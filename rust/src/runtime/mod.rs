//! Compute-backend layer: how model fwd/bwd and the L1 quantizer kernels
//! execute.
//!
//! The [`Backend`] trait is the seam between the distributed runtime (L3)
//! and the compute substrate:
//!
//! * [`NativeBackend`] (default) — pure Rust reference implementation; no
//!   Python, XLA or artifacts required. See [`native`].
//! * `PjrtBackend` (cargo feature `pjrt`) — AOT-compiled JAX/Pallas HLO
//!   loaded from `artifacts/` and executed through PJRT. See [`pjrt`]. In
//!   builds without real xla-rs bindings it compiles against [`xla_stub`]
//!   and fails fast at open time.
//!
//! Construction goes through [`make_backend`] (from an experiment config) or
//! [`backend_for`] (explicit kind), so `coordinator`, `train` and the CLI
//! select the compute path at runtime.

pub mod backend;
pub mod manifest;
pub mod native;

#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub mod quant_exec;
#[cfg(feature = "pjrt")]
pub mod xla_stub;

pub use backend::{backend_for, make_backend, Backend, EvalResult, GradResult, QuantKernel};
pub use manifest::{ArtifactSpec, GroupRange, Manifest, ModelSpec};
pub use native::{NativeBackend, NativeQuantKernel};

#[cfg(feature = "pjrt")]
pub use pjrt::{Executable, PjrtBackend, Runtime};
#[cfg(feature = "pjrt")]
pub use quant_exec::QuantExec;
