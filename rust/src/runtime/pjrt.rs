//! PJRT runtime (cargo feature `pjrt`): load `artifacts/*.hlo.txt`, compile
//! once, execute from the rust hot path. Python never runs here — the
//! artifacts directory is the entire L2/L1 interface (HLO text +
//! `manifest.json` + init params).
//!
//! `PjRtClient` wraps an `Rc`, so the runtime is deliberately
//! single-threaded: the coordinator calls PJRT from one thread and
//! parallelizes the pure-rust codec work instead (see `coordinator`).
//!
//! In this build the `xla` API resolves to the in-tree stub
//! ([`super::xla_stub`]): everything compiles and type-checks, and
//! [`Runtime::open`] reports a clear error until real xla-rs bindings are
//! linked. [`PjrtBackend`] adapts the runtime to the [`Backend`] trait so the
//! coordinator is oblivious to which compute path it runs on.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{anyhow, Context, Result};

use super::backend::{Backend, EvalResult, GradResult, QuantKernel};
use super::manifest::{ArtifactSpec, Manifest, ModelSpec};
use super::quant_exec::QuantExec;
use super::xla_stub as xla;

/// A loaded-and-compiled AOT entry point.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    /// Manifest entry name (e.g. `"cnn_grad"`).
    pub name: String,
    /// Input/output signature from the manifest.
    pub spec: ArtifactSpec,
}

impl Executable {
    /// Execute with flat f32 input buffers (shapes from the manifest) and
    /// return flat f32 outputs, one per manifest output.
    ///
    /// Scalars come back as single-element vectors.
    ///
    /// Inputs are transferred with `buffer_from_host_buffer` + `execute_b`
    /// rather than `execute(&[Literal])`: the crate's `execute` leaks the
    /// input device buffers (xla_rs.cc releases them and never frees), and
    /// the buffer path also skips one host-side copy.
    pub fn run(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                inputs.len()
            ));
        }
        let mut buffers = Vec::with_capacity(inputs.len());
        for (buf, ispec) in inputs.iter().zip(&self.spec.inputs) {
            let want: usize = ispec.shape.iter().product::<usize>().max(1);
            if buf.len() != want {
                return Err(anyhow!(
                    "{}: input {} expects {} elements ({:?}), got {}",
                    self.name,
                    ispec.name,
                    want,
                    ispec.shape,
                    buf.len()
                ));
            }
            let dims: Vec<usize> =
                if ispec.shape.is_empty() { vec![] } else { ispec.shape.clone() };
            buffers.push(self.client.buffer_from_host_buffer::<f32>(buf, &dims, None)?);
        }
        let result = self.exe.execute_b::<xla::PjRtBuffer>(&buffers)?;
        drop(buffers); // frees the input device buffers (leak fix)
        let tuple = result[0][0].to_literal_sync()?.to_tuple()?;
        let mut out = Vec::with_capacity(tuple.len());
        for (lit, ospec) in tuple.into_iter().zip(&self.spec.outputs) {
            // Integer outputs (e.g. quantizer indices) are converted via i32.
            if ospec.dtype == "i32" {
                let v: Vec<i32> = lit.to_vec()?;
                out.push(v.into_iter().map(|x| x as f32).collect());
            } else {
                out.push(lit.to_vec::<f32>()?);
            }
        }
        Ok(out)
    }
}

/// Artifact loader + executable cache over one PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    /// The parsed `manifest.json` contract.
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Runtime {
    /// Open an artifacts directory (must contain `manifest.json`).
    pub fn open(dir: impl Into<PathBuf>) -> Result<Runtime> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?} (run `make artifacts`)"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// PJRT platform name (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (or fetch from cache) a compiled entry point by manifest name.
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?
            .clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let executable = Rc::new(Executable {
            exe,
            client: self.client.clone(),
            name: name.to_string(),
            spec,
        });
        self.cache.borrow_mut().insert(name.to_string(), executable.clone());
        Ok(executable)
    }

    /// Read a model's initial flat parameters (f32-LE .bin).
    pub fn init_params(&self, model: &str) -> Result<Vec<f32>> {
        let spec = self.model(model)?;
        let path = self.dir.join(&spec.init_file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading init params {path:?}"))?;
        if bytes.len() != spec.param_count * 4 {
            return Err(anyhow!(
                "{model}: init file has {} bytes, expected {}",
                bytes.len(),
                spec.param_count * 4
            ));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Look up a model's manifest entry.
    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.manifest.models.get(name).ok_or_else(|| {
            anyhow!(
                "model {name:?} not in manifest (have: {:?})",
                self.manifest.models.keys().collect::<Vec<_>>()
            )
        })
    }
}

/// [`Backend`] adapter over the PJRT [`Runtime`].
pub struct PjrtBackend {
    rt: Runtime,
}

impl PjrtBackend {
    /// Open a backend over an AOT artifacts directory.
    pub fn open(dir: impl Into<PathBuf>) -> Result<PjrtBackend> {
        Ok(PjrtBackend { rt: Runtime::open(dir)? })
    }

    /// The underlying runtime, for artifact-level access (parity tests).
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> String {
        format!("pjrt ({})", self.rt.platform())
    }

    fn models(&self) -> Vec<String> {
        self.rt.manifest.models.keys().cloned().collect()
    }

    fn model(&self, name: &str) -> Result<ModelSpec> {
        Ok(self.rt.model(name)?.clone())
    }

    fn init_params(&self, model: &str) -> Result<Vec<f32>> {
        self.rt.init_params(model)
    }

    fn grad(&self, model: &str, params: &[f32], x: &[f32], y: &[f32]) -> Result<GradResult> {
        let spec = self.rt.model(model)?.clone();
        let exe = self.rt.load(&spec.grad_entry)?;
        let mut outs =
            if y.is_empty() { exe.run(&[params, x])? } else { exe.run(&[params, x, y])? };
        if outs.len() != 2 || outs[0].is_empty() {
            return Err(anyhow!("{model}: grad entry returned a malformed output tuple"));
        }
        let grads = outs.pop().unwrap();
        Ok(GradResult { loss: outs[0][0], grads })
    }

    fn eval(&self, model: &str, params: &[f32], x: &[f32], y: &[f32]) -> Result<EvalResult> {
        let spec = self.rt.model(model)?.clone();
        let exe = self.rt.load(&spec.eval_entry)?;
        let outs =
            if y.is_empty() { exe.run(&[params, x])? } else { exe.run(&[params, x, y])? };
        if outs.len() != 2 || outs[0].is_empty() || outs[1].is_empty() {
            return Err(anyhow!("{model}: eval entry returned a malformed output tuple"));
        }
        Ok(EvalResult { loss_sum: outs[0][0] as f64, count: outs[1][0] as f64 })
    }

    fn quant_kernel(&self, entry: &str) -> Result<Box<dyn QuantKernel>> {
        Ok(Box::new(QuantExec::new(&self.rt, entry)?))
    }
}
