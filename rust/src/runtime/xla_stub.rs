//! Stub of the `xla-rs` API surface the PJRT path compiles against.
//!
//! The build image has no XLA/PJRT shared libraries, so the `pjrt` cargo
//! feature compiles the full runtime wiring against this stub instead of the
//! real bindings. Every *entry* constructor ([`PjRtClient::cpu`],
//! [`HloModuleProto::from_text_file`]) returns an error, so the PJRT backend
//! fails fast at `Runtime::open` with a clear message; downstream methods are
//! therefore unreachable and panic if somehow invoked.
//!
//! To link the real runtime, replace this module with `use xla::*` from the
//! actual `xla-rs` bindings (the method signatures below mirror them 1:1)
//! and add the crate to `Cargo.toml` — no other file changes are needed.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error`; converts into `anyhow::Error` via `?`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla (stub): {}", self.0)
    }
}

impl std::error::Error for Error {}

/// `Result` alias mirroring `xla::Result`.
pub type Result<T> = std::result::Result<T, Error>;

const STUB_MSG: &str = "tqsgd was built with the in-tree PJRT stub; link the real xla-rs \
     bindings (see rust/src/runtime/xla_stub.rs) or use the default NativeBackend";

/// Element types transferable to/from device buffers.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Stub of `xla::PjRtClient`.
#[derive(Clone)]
pub struct PjRtClient;

impl PjRtClient {
    /// Real bindings: create a CPU PJRT client. Stub: always errors.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error(STUB_MSG.to_string()))
    }

    /// Platform name of the underlying PJRT client.
    pub fn platform_name(&self) -> String {
        unreachable!("stub PjRtClient cannot be constructed")
    }

    /// Compile an XLA computation into a loaded executable.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unreachable!("stub PjRtClient cannot be constructed")
    }

    /// Transfer a host buffer to the device.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unreachable!("stub PjRtClient cannot be constructed")
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with device buffers, returning per-device output buffers.
    pub fn execute_b<B>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unreachable!("stub PjRtLoadedExecutable cannot be constructed")
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the device buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unreachable!("stub PjRtBuffer cannot be constructed")
    }
}

/// Stub of `xla::Literal`.
pub struct Literal;

impl Literal {
    /// Destructure a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unreachable!("stub Literal cannot be constructed")
    }

    /// Copy out the flat element data.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unreachable!("stub Literal cannot be constructed")
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Real bindings: parse HLO text. Stub: always errors.
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error(STUB_MSG.to_string()))
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module as a computation.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
