//! §Perf — isolated kernel microbenchmarks: the dispatched SIMD kernels
//! (`quant::simd::detected_kernels()`) against the scalar reference
//! (`quant::simd::scalar_kernels()`), same inputs, same RNG streams.
//!
//! Every timed pair is byte-checked for equality first — the dispatch
//! contract is *bit-identical or bust*, so a speedup on diverging output
//! would be meaningless. Alongside wall-clock numbers the report carries
//! two runner-speed-independent facts: the packed bytes/element of each
//! width (pure arithmetic, identical on every machine) and the
//! deterministic work-unit count (elements quantized per timed closure),
//! so two BENCH_perf_kernels.json files from different hardware can still
//! be compared structurally.
//!
//! Regenerate with `cargo bench --bench perf_kernels`; CI runs
//! `-- --quick` with `TQSGD_BENCH_JSON=BENCH_perf_kernels.json` and gates
//! `kernel_encode_b4_melems_per_s` against `BENCH_baseline.json`
//! (`tqsgd perf-check`). `TQSGD_FORCE_SCALAR=1` turns the dispatched
//! column into a second scalar run (useful for measuring harness noise).

use tqsgd::benchkit::{bench, section, BenchOpts, Report, Table};
use tqsgd::quant::bitpack;
use tqsgd::quant::simd::{detected_kernels, scalar_kernels};
use tqsgd::util::Rng;

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::from_env_and_args();
    let mut report = Report::new("perf_kernels", &opts);
    let (warmup, runs) = if opts.quick { (1, 4) } else { (2, 8) };
    let mut rng = Rng::new(99);
    let d = 1 << 20; // 1M elements, matching perf_hotpath's working set
    let grads: Vec<f32> =
        (0..d).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();

    let sc = scalar_kernels();
    let dt = detected_kernels();
    println!(
        "kernel tables: scalar = {}, detected = {}, active = {}",
        sc.isa,
        dt.isa,
        tqsgd::quant::simd::active_kernels().isa
    );
    report.metric("kernel_bench_work_melems", d as f64 / 1e6);

    // One-shot bit-identity checks on the exact benchmark inputs. Cheap
    // relative to the timed runs, and they turn a silent divergence into a
    // loud bench failure (the property suite covers the general case).
    let alpha = 0.05f32;
    for bits in [2u32, 4, 8, 12] {
        let s = (1u32 << bits) - 1;
        let (mut a, mut b) = (Vec::new(), Vec::new());
        let (mut r1, mut r2) = (Rng::new(1), Rng::new(1));
        (sc.quantize_uniform_pack_into)(&grads, &mut r1, alpha, s, bits, &mut a);
        (dt.quantize_uniform_pack_into)(&grads, &mut r2, alpha, s, bits, &mut b);
        assert_eq!(a, b, "uniform b{bits}: dispatched bytes differ from scalar");
    }
    let codebook: Vec<f32> =
        vec![-0.9, -0.45, -0.15, -0.03, 0.03, 0.15, 0.45, 0.9];
    let (mut a, mut b) = (Vec::new(), Vec::new());
    let (mut r1, mut r2) = (Rng::new(1), Rng::new(1));
    (sc.quantize_codebook_pack_into)(&grads, &mut r1, &codebook, 3, &mut a);
    (dt.quantize_codebook_pack_into)(&grads, &mut r2, &codebook, 3, &mut b);
    assert_eq!(a, b, "codebook b3: dispatched bytes differ from scalar");
    let mut wlut = [0.0f32; 256];
    for (w, &c) in wlut.iter_mut().zip(&codebook) {
        *w = 0.125 * c;
    }
    let (mut acc_s, mut acc_d) = (vec![0.0f32; d], vec![0.0f32; d]);
    (sc.accumulate_packed_wlut)(&a, 3, codebook.len(), &wlut, &mut acc_s).unwrap();
    (dt.accumulate_packed_wlut)(&b, 3, codebook.len(), &wlut, &mut acc_d).unwrap();
    assert!(
        acc_s.iter().zip(&acc_d).all(|(x, y)| x.to_bits() == y.to_bits()),
        "accumulate b3: dispatched sums differ from scalar"
    );
    assert_eq!(
        (sc.max_abs)(&grads).to_bits(),
        (dt.max_abs)(&grads).to_bits(),
        "max_abs: dispatched result differs from scalar"
    );
    println!("bit-identity spot checks passed ({} vs {})", sc.isa, dt.isa);

    section("uniform quantize+pack (1M elements, single core)");
    let mut t = Table::new(&[
        "bits",
        "scalar",
        "dispatched",
        "speedup",
        "Melem/s",
        "bytes/elem",
    ]);
    for bits in [2u32, 4, 8, 12] {
        let s = (1u32 << bits) - 1;
        let mut buf = Vec::new();
        let t_sc = bench(warmup, runs, || {
            let mut r = Rng::new(1);
            buf.clear();
            (sc.quantize_uniform_pack_into)(&grads, &mut r, alpha, s, bits, &mut buf);
            std::hint::black_box(&buf);
        });
        let t_dt = bench(warmup, runs, || {
            let mut r = Rng::new(1);
            buf.clear();
            (dt.quantize_uniform_pack_into)(&grads, &mut r, alpha, s, bits, &mut buf);
            std::hint::black_box(&buf);
        });
        let bytes_per_elem = bitpack::packed_len(d, bits) as f64 / d as f64;
        t.row(&[
            bits.to_string(),
            t_sc.pretty(),
            t_dt.pretty(),
            format!("{:.2}x", t_sc.median_ns / t_dt.median_ns),
            format!("{:.1}", t_dt.melems_per_s(d)),
            format!("{bytes_per_elem:.3}"),
        ]);
        if bits == 4 {
            report.metric("kernel_encode_b4_melems_per_s", t_dt.melems_per_s(d));
            report.metric("kernel_encode_b4_scalar_melems_per_s", t_sc.melems_per_s(d));
            report.metric(
                "kernel_encode_b4_simd_speedup",
                t_sc.median_ns / t_dt.median_ns,
            );
            report.metric("kernel_encode_b4_bytes_per_elem", bytes_per_elem);
        }
    }
    t.print();
    report.table("uniform quantize+pack (1M elements)", &t);

    section("codebook quantize+pack / accumulate / max_abs (1M elements)");
    let mut t = Table::new(&["kernel", "scalar", "dispatched", "speedup", "Melem/s"]);
    let mut buf = Vec::new();
    let t_sc = bench(warmup, runs, || {
        let mut r = Rng::new(1);
        buf.clear();
        (sc.quantize_codebook_pack_into)(&grads, &mut r, &codebook, 3, &mut buf);
        std::hint::black_box(&buf);
    });
    let t_dt = bench(warmup, runs, || {
        let mut r = Rng::new(1);
        buf.clear();
        (dt.quantize_codebook_pack_into)(&grads, &mut r, &codebook, 3, &mut buf);
        std::hint::black_box(&buf);
    });
    t.row(&[
        "codebook b3".to_string(),
        t_sc.pretty(),
        t_dt.pretty(),
        format!("{:.2}x", t_sc.median_ns / t_dt.median_ns),
        format!("{:.1}", t_dt.melems_per_s(d)),
    ]);
    report.metric("kernel_codebook_b3_melems_per_s", t_dt.melems_per_s(d));

    // `buf` now holds the codebook frame bytes from the last timed run
    // (Rng::new(1) stream) — the accumulate input.
    let mut acc = vec![0.0f32; d];
    let t_sc = bench(warmup, runs, || {
        acc.iter_mut().for_each(|v| *v = 0.0);
        (sc.accumulate_packed_wlut)(&buf, 3, codebook.len(), &wlut, &mut acc).unwrap();
        std::hint::black_box(&acc);
    });
    let t_dt = bench(warmup, runs, || {
        acc.iter_mut().for_each(|v| *v = 0.0);
        (dt.accumulate_packed_wlut)(&buf, 3, codebook.len(), &wlut, &mut acc).unwrap();
        std::hint::black_box(&acc);
    });
    t.row(&[
        "accumulate b3".to_string(),
        t_sc.pretty(),
        t_dt.pretty(),
        format!("{:.2}x", t_sc.median_ns / t_dt.median_ns),
        format!("{:.1}", t_dt.melems_per_s(d)),
    ]);
    report.metric("kernel_accumulate_b3_melems_per_s", t_dt.melems_per_s(d));

    let t_sc = bench(warmup, runs, || {
        std::hint::black_box((sc.max_abs)(&grads));
    });
    let t_dt = bench(warmup, runs, || {
        std::hint::black_box((dt.max_abs)(&grads));
    });
    t.row(&[
        "max_abs".to_string(),
        t_sc.pretty(),
        t_dt.pretty(),
        format!("{:.2}x", t_sc.median_ns / t_dt.median_ns),
        format!("{:.1}", t_dt.melems_per_s(d)),
    ]);
    report.metric("kernel_max_abs_melems_per_s", t_dt.melems_per_s(d));
    t.print();
    report.table("codebook / accumulate / max_abs (1M elements)", &t);

    report.finish(&opts)?;
    Ok(())
}
