//! Fig. 5 / Appendix D — Truncated BiScaled Quantization (TBQSGD): the
//! two-region density, the solved (k*, s_α, s_β, α*) design, and the
//! Theorem 3 bound; plus a training comparison at b = 3.
//!
//! Paper shape: Q_B(α*, k*) ≤ 1 (Hölder), TBQSGD's E_TQ beats TQSGD's and
//! its accuracy is competitive with TNQSGD at the same budget.
//!
//! Regenerate with `cargo bench --bench fig5_biscaled`.

use tqsgd::benchkit::{section, BenchOpts, Report, Table};
use tqsgd::config::{ExperimentConfig, Scheme};
use tqsgd::solver::{self, levels_for_bits};
use tqsgd::tail::PowerLawModel;
use tqsgd::theory;
use tqsgd::train::Sweep;

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::from_env_and_args();
    let mut report = Report::new("fig5_biscaled", &opts);
    section("Fig. 5 — BiScaled design across tail indices (b=3)");
    let s = levels_for_bits(3);
    let mut t = Table::new(&[
        "γ", "α*", "β*", "k*", "s_β", "s_α", "Q_B", "E_TQ(TBQ)", "E_TQ(TQ)", "E_TQ(TNQ)",
    ]);
    for &gamma in &[3.2, 3.5, 4.0, 4.5, 5.0] {
        let m = PowerLawModel::new(gamma, 0.01, 0.1);
        let d = solver::solve_biscaled(&m, s);
        let e_b = solver::e_tq_biscaled(&m, &d, s);
        let e_u = solver::e_tq_uniform(&m, solver::optimal_alpha_uniform(&m, s), s);
        let e_n = solver::e_tq_nonuniform(&m, solver::optimal_alpha_nonuniform(&m, s), s);
        t.row(&[
            format!("{gamma:.1}"),
            format!("{:.4}", d.alpha),
            format!("{:.4}", d.beta),
            format!("{:.3}", d.k),
            d.s_beta.to_string(),
            d.s_alpha.to_string(),
            format!("{:.4}", d.q_b),
            format!("{e_b:.3e}"),
            format!("{e_u:.3e}"),
            format!("{e_n:.3e}"),
        ]);
    }
    t.print();
    report.table("BiScaled design across tail indices", &t);

    section("Theorem 3 bound vs Theorems 1/2 (d=37610, N=8)");
    let mut tb = Table::new(&["s", "Thm1 (TQSGD)", "Thm2 (TNQSGD)", "Thm3 (TBQSGD)", "ordering"]);
    let m = PowerLawModel::new(4.0, 0.01, 0.1);
    for &s in &[3usize, 7, 15, 31] {
        let t1 = theory::theorem1_bound(&m, 37610, 8, s);
        let t2 = theory::theorem2_bound(&m, 37610, 8, s);
        let t3 = theory::theorem3_bound(&m, 37610, 8, s);
        tb.row(&[
            s.to_string(),
            format!("{t1:.3e}"),
            format!("{t2:.3e}"),
            format!("{t3:.3e}"),
            format!(
                "{}",
                if t2 <= t1 && t3 <= t1 { "Thm2 ≤ Thm1, Thm3 ≤ Thm1 ✓" } else { "VIOLATED" }
            ),
        ]);
    }
    tb.print();
    report.table("Theorem 3 bound vs Theorems 1/2", &tb);

    let rounds = opts.size("TQSGD_BENCH_ROUNDS", 250, 25);
    section(&format!("training comparison at b=3 ({rounds} rounds)"));
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp".into();
    cfg.lr = 0.05;
    cfg.rounds = rounds;
    cfg.eval_every = rounds;
    cfg.quant.bits = 3;
    let sweep = Sweep::new(&cfg.artifacts_dir)?;
    let mut res = Table::new(&["scheme", "final acc", "bits/param/round"]);
    for scheme in [Scheme::Tqsgd, Scheme::Tnqsgd, Scheme::Tbqsgd] {
        let mut c = cfg.clone();
        c.quant.scheme = scheme;
        let r = sweep.run(c, false)?;
        res.row(&[
            scheme.name().into(),
            format!("{:.4}", r.final_accuracy),
            format!("{:.2}", r.bits_per_param),
        ]);
    }
    res.print();
    report.table("training comparison at b=3", &res);
    report.finish(&opts)?;
    Ok(())
}
