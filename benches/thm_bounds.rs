//! Theorems 1 & 2 — the E_TQ convergence-error terms at the optimized
//! parameters:
//!
//! * fixed points Eq. (12)/(19) satisfied,
//! * measured per-element quantization MSE at α* matches d/N · E_TQ scaled
//!   back (we measure the per-element term itself),
//! * the communication scaling E_TQ ∝ s^{(6−2γ)/(γ−1)} — the paper's
//!   headline rate — recovered as a log-log slope,
//! * Hölder ordering Q_N ≤ Q_U ⇒ Thm 2 ≤ Thm 1,
//! * the Eq. (13)-vs-(14) approximation gap ε ≤ 2[1 − Q_U(α')].
//!
//! Regenerate with `cargo bench --bench thm_bounds`.

use tqsgd::benchkit::{section, BenchOpts, Report, Table};
use tqsgd::quant::kernels::{dequantize_uniform_elem, quantize_codebook_elem, quantize_uniform_elem};
use tqsgd::solver::{self, levels_for_bits};
use tqsgd::tail::PowerLawModel;
use tqsgd::theory;
use tqsgd::util::Rng;

fn measured_e_tq_uniform(m: &PowerLawModel, s: usize, rng: &mut Rng, n: usize) -> f64 {
    let alpha = solver::optimal_alpha_uniform(m, s) as f32;
    let mut mse = 0.0;
    for _ in 0..n {
        let g = rng.power_law_gradient(m.g_min, m.gamma, 2.0 * m.rho) as f32;
        let idx = quantize_uniform_elem(g, rng.f32(), alpha, s as u32);
        mse += ((dequantize_uniform_elem(idx, alpha, s as u32) - g) as f64).powi(2);
    }
    mse / n as f64
}

fn measured_e_tq_nonuniform(m: &PowerLawModel, s: usize, rng: &mut Rng, n: usize) -> f64 {
    let alpha = solver::optimal_alpha_nonuniform(m, s);
    let cb = solver::nonuniform_codebook(m, alpha, s);
    let mut mse = 0.0;
    for _ in 0..n {
        let g = rng.power_law_gradient(m.g_min, m.gamma, 2.0 * m.rho) as f32;
        let idx = quantize_codebook_elem(g, rng.f32(), &cb);
        mse += ((cb[idx as usize] - g) as f64).powi(2);
    }
    mse / n as f64
}

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::from_env_and_args();
    let mut report = Report::new("thm_bounds", &opts);
    let n = opts.size("TQSGD_BENCH_SAMPLES", 150_000, 15_000);
    let mut rng = Rng::new(2024);

    for &gamma in &[3.5f64, 4.0, 4.5] {
        let m = PowerLawModel::new(gamma, 0.01, 0.1);
        section(&format!("Theorems 1/2 — γ = {gamma} (per-element E_TQ, d=N=1)"));
        let mut t = Table::new(&[
            "b", "s", "E_TQ thm1", "measured TQSGD", "E_TQ thm2", "measured TNQSGD", "thm2≤thm1",
        ]);
        for &b in &[2u32, 3, 4, 5] {
            let s = levels_for_bits(b);
            let t1 = theory::theorem1_bound(&m, 1, 1, s);
            let t2 = theory::theorem2_bound(&m, 1, 1, s);
            let m1 = measured_e_tq_uniform(&m, s, &mut rng, n);
            let m2 = measured_e_tq_nonuniform(&m, s, &mut rng, n);
            t.row(&[
                b.to_string(),
                s.to_string(),
                format!("{t1:.3e}"),
                format!("{m1:.3e}"),
                format!("{t2:.3e}"),
                format!("{m2:.3e}"),
                (t2 <= t1 * 1.0000001).to_string(),
            ]);
        }
        t.print();
        report.table(&format!("Theorems 1/2 — γ = {gamma}"), &t);

        // Communication-scaling slope.
        let t_a = theory::theorem1_bound(&m, 1, 1, 7);
        let t_b = theory::theorem1_bound(&m, 1, 1, 31);
        let slope = (t_b / t_a).ln() / (31.0f64 / 7.0).ln();
        let expect = (6.0 - 2.0 * gamma) / (gamma - 1.0);
        let m_a = measured_e_tq_uniform(&m, 7, &mut rng, n);
        let m_b = measured_e_tq_uniform(&m, 31, &mut rng, n);
        let slope_meas = (m_b / m_a).ln() / (31.0f64 / 7.0).ln();
        println!(
            "scaling E_TQ ∝ s^x: theory x = {expect:.3}, bound slope = {slope:.3}, measured slope = {slope_meas:.3}"
        );

        let (eps, bound) = theory::theorem1_approx_gap(&m, 7);
        println!(
            "Eq.(13) vs Eq.(14) gap: ε = {eps:.4} ≤ 2[1 − Q_U(α')] = {bound:.4} → {}",
            if eps <= bound + 1e-9 { "HOLDS" } else { "VIOLATED" }
        );
    }
    report.finish(&opts)?;
    Ok(())
}
