//! Fig. 4 — "Communication-learning tradeoff": final test accuracy vs
//! communication budget b ∈ {2,3,4,5} for QSGD / NQSGD / TQSGD / TNQSGD,
//! with DSGD as the uncompressed anchor.
//!
//! Paper shape: every curve is increasing in b; the truncated schemes
//! dominate at every budget; gaps shrink as b grows (all converge toward
//! DSGD).  Includes an error-feedback ablation (our extension).
//!
//! Regenerate with `cargo bench --bench fig4_tradeoff`
//! (`TQSGD_BENCH_ROUNDS=600` for tighter curves).

use tqsgd::benchkit::{section, BenchOpts, Report, Table};
use tqsgd::config::{ExperimentConfig, Scheme};
use tqsgd::train::Sweep;

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::from_env_and_args();
    let mut report = Report::new("fig4_tradeoff", &opts);
    let rounds = opts.size("TQSGD_BENCH_ROUNDS", 250, 25);
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp".into();
    cfg.lr = 0.05; // operating point where low-bit noise separates schemes
    cfg.rounds = rounds;
    cfg.eval_every = rounds;

    section(&format!("Fig. 4 — accuracy vs bits, {} rounds, N=8", rounds));
    let sweep = Sweep::new(&cfg.artifacts_dir)?;

    let mut dc = cfg.clone();
    dc.quant.scheme = Scheme::Dsgd;
    let anchor = sweep.run(dc, false)?;
    println!("DSGD anchor (32-bit): acc {:.4}", anchor.final_accuracy);

    let schemes = [Scheme::Qsgd, Scheme::Nqsgd, Scheme::Tqsgd, Scheme::Tnqsgd];
    let bits = [2u32, 3, 4, 5];
    let mut results = std::collections::BTreeMap::new();
    for scheme in schemes {
        for b in bits {
            let mut c = cfg.clone();
            c.quant.scheme = scheme;
            c.quant.bits = b;
            let r = sweep.run(c, false)?;
            eprintln!("  {} b={}: acc {:.4}", scheme.name(), b, r.final_accuracy);
            results.insert((scheme.name().to_string(), b), r);
        }
    }

    let mut table = Table::new(&["bits", "qsgd", "nqsgd", "tqsgd", "tnqsgd", "MB up (tnqsgd)"]);
    for b in bits {
        table.row(&[
            b.to_string(),
            format!("{:.4}", results[&("qsgd".into(), b)].final_accuracy),
            format!("{:.4}", results[&("nqsgd".into(), b)].final_accuracy),
            format!("{:.4}", results[&("tqsgd".into(), b)].final_accuracy),
            format!("{:.4}", results[&("tnqsgd".into(), b)].final_accuracy),
            format!("{:.1}", results[&("tnqsgd".into(), b)].total_bytes_up as f64 / 1e6),
        ]);
    }
    table.print();
    report.table("accuracy vs bits", &table);

    section("paper-shape checks");
    for scheme in ["tqsgd", "tnqsgd"] {
        let a2 = results[&(scheme.to_string(), 2)].final_accuracy;
        let a5 = results[&(scheme.to_string(), 5)].final_accuracy;
        println!(
            "[{}] {scheme}: accuracy increases with budget ({a2:.4} @b2 → {a5:.4} @b5)",
            if a5 >= a2 - 0.01 { "PASS" } else { "FAIL" }
        );
    }
    for b in bits {
        let tq = results[&("tqsgd".into(), b)].final_accuracy;
        let q = results[&("qsgd".into(), b)].final_accuracy;
        println!(
            "[{}] b={b}: truncated ≥ plain uniform ({tq:.4} vs {q:.4})",
            if tq >= q - 0.02 { "PASS" } else { "FAIL" }
        );
    }

    section("extension ablation: error feedback on TQSGD b=2");
    let mut ef = cfg.clone();
    ef.quant.scheme = Scheme::Tqsgd;
    ef.quant.bits = 2;
    ef.quant.error_feedback = true;
    let r_ef = sweep.run(ef, false)?;
    let r_plain = &results[&("tqsgd".into(), 2)];
    println!(
        "tqsgd b=2: plain {:.4} vs +error-feedback {:.4}",
        r_plain.final_accuracy, r_ef.final_accuracy
    );
    report.metric("tqsgd_b2_ef_final_acc", r_ef.final_accuracy);
    report.finish(&opts)?;
    Ok(())
}
