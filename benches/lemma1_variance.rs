//! Lemma 1 — unbiasedness `E[Q[g]] = g` and the variance bound
//! `E‖Q[g]−g‖² ≤ Σ_k P_k |Δ_k|² / 4`, measured by Monte-Carlo over the
//! rust codec and compared with the closed-form prediction.
//!
//! Regenerate with `cargo bench --bench lemma1_variance`.

use tqsgd::benchkit::{section, BenchOpts, Report, Table};
use tqsgd::quant::kernels::{dequantize_uniform_elem, quantize_codebook_elem, quantize_uniform_elem};
use tqsgd::solver::{nonuniform_codebook, optimal_alpha_nonuniform, optimal_alpha_uniform, uniform_codebook};
use tqsgd::tail::PowerLawModel;
use tqsgd::theory::lemma1_variance_bound;
use tqsgd::util::Rng;

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::from_env_and_args();
    let mut report = Report::new("lemma1_variance", &opts);
    let n = opts.size("TQSGD_BENCH_SAMPLES", 250_000, 25_000);
    let m = PowerLawModel::new(4.0, 0.01, 0.1);
    let mut rng = Rng::new(42);
    // Draw heavy-tailed gradients from the paper's model.
    let grads: Vec<f32> =
        (0..n).map(|_| rng.power_law_gradient(m.g_min, m.gamma, 2.0 * m.rho) as f32).collect();

    section("Lemma 1 — uniform codebook (TQSGD)");
    let mut t = Table::new(&["s", "α*", "bias |E[Q−g]| (in-range)", "measured var", "Σ P_k Δ_k²/4 bound", "within"]);
    for &s in &[3usize, 7, 15, 31] {
        let alpha = optimal_alpha_uniform(&m, s) as f32;
        let mut bias = 0.0f64;
        let mut var = 0.0f64;
        let mut n_in = 0usize;
        for &g in &grads {
            let idx = quantize_uniform_elem(g, rng.f32(), alpha, s as u32);
            let q = dequantize_uniform_elem(idx, alpha, s as u32);
            let gc = g.clamp(-alpha, alpha);
            var += ((q - gc) as f64).powi(2);
            if g.abs() <= alpha {
                bias += (q - g) as f64;
                n_in += 1;
            }
        }
        var /= grads.len() as f64;
        bias = (bias / n_in as f64).abs();
        let bound = lemma1_variance_bound(&m, &uniform_codebook(alpha as f64, s));
        t.row(&[
            s.to_string(),
            format!("{alpha:.4}"),
            format!("{bias:.2e}"),
            format!("{var:.3e}"),
            format!("{bound:.3e}"),
            (var <= bound * 1.02).to_string(),
        ]);
    }
    t.print();
    report.table("Lemma 1 — uniform codebook (TQSGD)", &t);

    section("Lemma 1 — optimal non-uniform codebook (TNQSGD, Eq. 18)");
    let mut t2 = Table::new(&["s", "α*", "measured var", "Σ P_k Δ_k²/4 bound", "within", "vs uniform var"]);
    for &s in &[7usize, 15, 31] {
        let alpha = optimal_alpha_nonuniform(&m, s);
        let cb = nonuniform_codebook(&m, alpha, s);
        let mut var = 0.0f64;
        for &g in &grads {
            let idx = quantize_codebook_elem(g, rng.f32(), &cb);
            let q = cb[idx as usize];
            let gc = g.clamp(cb[0], cb[s]);
            var += ((q - gc) as f64).powi(2);
        }
        var /= grads.len() as f64;
        let bound = lemma1_variance_bound(&m, &cb);
        // Uniform comparison at the same alpha and s.
        let cb_u = uniform_codebook(alpha, s);
        let mut var_u = 0.0f64;
        for &g in &grads {
            let idx = quantize_codebook_elem(g, rng.f32(), &cb_u);
            let q = cb_u[idx as usize];
            let gc = g.clamp(cb_u[0], cb_u[s]);
            var_u += ((q - gc) as f64).powi(2);
        }
        var_u /= grads.len() as f64;
        t2.row(&[
            s.to_string(),
            format!("{alpha:.4}"),
            format!("{var:.3e}"),
            format!("{bound:.3e}"),
            (var <= bound * 1.02).to_string(),
            format!("{:.2}x lower", var_u / var),
        ]);
    }
    t2.print();
    report.table("Lemma 1 — non-uniform codebook (TNQSGD)", &t2);
    println!("\n(unbiasedness holds for truncated values; variance within the Lemma 1 bound)");
    report.finish(&opts)?;
    Ok(())
}
