//! Fig. 2 — the two-stage quantizer's structure: with truncation range
//! [−α, α] and b = 3 (s = 7 intervals), the non-uniform density assigns
//! more levels near the distribution peak and fewer in the tails —
//! |l_4 − l_3| < |l_1 − l_0| in the paper's figure.
//!
//! Regenerate with `cargo bench --bench fig2_codebook`.

use tqsgd::benchkit::{section, BenchOpts, Report, Table};
use tqsgd::solver::{
    levels_for_bits, nonuniform_codebook, optimal_alpha_nonuniform, optimal_alpha_uniform,
    solve_biscaled, uniform_codebook,
};
use tqsgd::tail::PowerLawModel;

fn print_codebook(report: &mut Report, name: &str, cb: &[f32]) {
    let s = cb.len() - 1;
    let mut t = Table::new(&["k", "l_k", "|Δ_k| = l_k − l_{k−1}"]);
    for k in 0..=s {
        t.row(&[
            k.to_string(),
            format!("{:+.5}", cb[k]),
            if k == 0 { "—".into() } else { format!("{:.5}", cb[k] - cb[k - 1]) },
        ]);
    }
    println!("\n{name}:");
    t.print();
    report.table(name, &t);
}

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::from_env_and_args();
    let mut report = Report::new("fig2_codebook", &opts);
    let m = PowerLawModel::new(4.0, 0.01, 0.1);
    let b = 3;
    let s = levels_for_bits(b);
    section(&format!(
        "Fig. 2 — two-stage quantizer structure (γ={}, g_min={}, ρ={}, b={b}, s={s})",
        m.gamma, m.g_min, m.rho
    ));

    let a_u = optimal_alpha_uniform(&m, s);
    let cb_u = uniform_codebook(a_u, s);
    print_codebook(&mut report, &format!("TQSGD uniform codebook (α*={a_u:.5})"), &cb_u);

    let a_n = optimal_alpha_nonuniform(&m, s);
    let cb_n = nonuniform_codebook(&m, a_n, s);
    print_codebook(&mut report, &format!("TNQSGD non-uniform codebook (α*={a_n:.5})"), &cb_n);

    let d = solve_biscaled(&m, s);
    let cb_b = d.codebook();
    print_codebook(
        &mut report,
        &format!(
            "TBQSGD BiScaled codebook (α*={:.5}, β*={:.5}, k*={:.3}, s_β={}, s_α={})",
            d.alpha, d.beta, d.k, d.s_beta, d.s_alpha
        ),
        &cb_b,
    );

    // Paper's visual claim: the central interval is narrower than the edge
    // interval for the non-uniform quantizer.
    let central = cb_n[s / 2 + 1] - cb_n[s / 2];
    let edge = cb_n[1] - cb_n[0];
    println!(
        "\npaper claim |l_4 − l_3| < |l_1 − l_0|: central {central:.5} vs edge {edge:.5} → {}",
        if central < edge { "HOLDS" } else { "VIOLATED" }
    );
    println!(
        "truncation thresholds: α*(TNQSGD) {a_n:.5} ≥ α*(TQSGD) {a_u:.5} (Hölder corollary) → {}",
        if a_n >= a_u { "HOLDS" } else { "VIOLATED" }
    );
    report.metric("tnqsgd_alpha_star", a_n);
    report.metric("tqsgd_alpha_star", a_u);
    report.finish(&opts)?;
    Ok(())
}
