//! Fig. 3 — "Model performance of different algorithms": test-accuracy
//! curves at b = 3 bits, N = 8 clients, momentum SGD (lr .01, µ .9,
//! wd 5e-4), conv/fc quantized independently.
//!
//! Paper numbers (AlexNet on MNIST): DSGD 0.9691, TNQSGD 0.9619,
//! TQSGD 0.9515, QSGD/NQSGD "almost unable to converge".  Our testbed is a
//! LeNet-style CNN on synthetic MNIST-like data, so absolute numbers differ;
//! the SHAPE to reproduce is the ordering
//!     DSGD ≥ TNQSGD ≥ TQSGD >> QSGD/NQSGD gap at the same budget.
//!
//! Regenerate with `cargo bench --bench fig3_accuracy`
//! (`TQSGD_BENCH_ROUNDS=800` for the full curves).

use tqsgd::benchkit::{section, BenchOpts, Report, Table};
use tqsgd::config::{ExperimentConfig, Scheme};
use tqsgd::train::Sweep;

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::from_env_and_args();
    let mut report = Report::new("fig3_accuracy", &opts);
    let rounds = opts.size("TQSGD_BENCH_ROUNDS", 300, 30);
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp".into();
    cfg.lr = 0.05; // operating point where 3-bit noise separates schemes (see EXPERIMENTS.md)
    cfg.rounds = rounds;
    cfg.eval_every = (rounds / 8).max(1);
    cfg.quant.bits = 3;

    section(&format!(
        "Fig. 3 — accuracy curves, b=3, N=8, {} rounds (paper: DSGD .9691 TNQSGD .9619 TQSGD .9515, QSGD/NQSGD diverge)",
        rounds
    ));

    let sweep = Sweep::new(&cfg.artifacts_dir)?;
    let schemes =
        [Scheme::Dsgd, Scheme::Qsgd, Scheme::Nqsgd, Scheme::Tqsgd, Scheme::Tnqsgd, Scheme::Tbqsgd];
    let mut curves = Vec::new();
    for scheme in schemes {
        let mut c = cfg.clone();
        c.quant.scheme = scheme;
        let r = sweep.run(c, false)?;
        eprintln!(
            "  {}: final acc {:.4} ({:.2} bits/param/round)",
            scheme.name(),
            r.final_accuracy,
            r.bits_per_param
        );
        curves.push((scheme, r));
    }

    // Curve table: rows = eval rounds, columns = schemes.
    let mut headers = vec!["round".to_string()];
    headers.extend(curves.iter().map(|(s, _)| s.name().to_string()));
    let mut table = Table::new(&headers.iter().map(|h| h.as_str()).collect::<Vec<_>>());
    let eval_rounds: Vec<usize> =
        curves[0].1.log.accuracy_series().iter().map(|&(r, _)| r).collect();
    for &er in &eval_rounds {
        let mut row = vec![er.to_string()];
        for (_, rep) in &curves {
            let acc = rep
                .log
                .accuracy_series()
                .iter()
                .find(|&&(r, _)| r == er)
                .map(|&(_, a)| a);
            row.push(acc.map_or("—".into(), |a| format!("{a:.4}")));
        }
        table.row(&row);
    }
    table.print();
    report.table("accuracy curves (b=3, N=8)", &table);
    for (scheme, rep) in &curves {
        report.metric(&format!("{}_final_acc", scheme.name()), rep.final_accuracy);
    }

    section("paper-shape checks");
    let get = |s: Scheme| curves.iter().find(|(c, _)| *c == s).unwrap().1.final_accuracy;
    let (dsgd, qsgd, nqsgd, tqsgd, tnqsgd, tbqsgd) = (
        get(Scheme::Dsgd),
        get(Scheme::Qsgd),
        get(Scheme::Nqsgd),
        get(Scheme::Tqsgd),
        get(Scheme::Tnqsgd),
        get(Scheme::Tbqsgd),
    );
    let checks: Vec<(String, bool)> = vec![
        (format!("DSGD ({dsgd:.4}) is the best or ties"), dsgd >= tnqsgd - 0.02),
        (format!("TNQSGD ({tnqsgd:.4}) ≥ TQSGD ({tqsgd:.4}) − ε"), tnqsgd >= tqsgd - 0.02),
        (
            format!("truncated ≥ untruncated: TQSGD ({tqsgd:.4}) vs QSGD ({qsgd:.4})"),
            tqsgd >= qsgd - 0.02,
        ),
        (
            // KNOWN DEVIATION: our NQSGD baseline re-fits its p^{1/3}
            // codebook every estimate_every rounds over [−max|g|, max|g|],
            // which acts as adaptive soft truncation — a STRONGER baseline
            // than the paper's static non-uniform quantizer. It therefore
            // tracks TNQSGD closely instead of diverging (see
            // EXPERIMENTS.md §Fig3). The b=2 column of Fig. 4 shows the
            // paper's collapse where even this baseline cannot compensate.
            format!("truncated ≈ adaptive-untruncated: TNQSGD ({tnqsgd:.4}) vs NQSGD ({nqsgd:.4})"),
            tnqsgd >= nqsgd - 0.05,
        ),
        (format!("TBQSGD ({tbqsgd:.4}) competitive with TQSGD"), tbqsgd >= tqsgd - 0.03),
    ];
    for (msg, ok) in checks {
        println!("[{}] {msg}", if ok { "PASS" } else { "FAIL" });
    }
    report.finish(&opts)?;
    Ok(())
}
