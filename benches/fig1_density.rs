//! Fig. 1 — "The probability density of gradient computed with LeNet on
//! MNIST": gradients from a real (small, synthetic-data) CNN training run
//! are heavy-tailed; Gaussian and Laplace fits have tails that are far too
//! thin, the power-law tail fit tracks the empirical density.
//!
//! Paper shape to reproduce: at deep-tail |g| (several σ), the empirical
//! density exceeds the Gaussian fit by orders of magnitude and the Laplace
//! fit by a large factor, while the power-law fit stays within a small
//! factor.  Regenerate with `cargo bench --bench fig1_density`
//! (`TQSGD_BENCH_ROUNDS` to harvest later-training gradients).

use tqsgd::benchkit::{section, BenchOpts, Report, Table};
use tqsgd::config::{ExperimentConfig, Scheme};
use tqsgd::coordinator::Coordinator;
use tqsgd::runtime::make_backend;
use tqsgd::tail::{fit::report_to_model, fit_gaussian, fit_laplace, fit_power_law, LogHistogram};
use tqsgd::util::math::{laplace_cdf, normal_cdf};

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::from_env_and_args();
    let mut report = Report::new("fig1_density", &opts);
    let rounds = opts.size("TQSGD_BENCH_ROUNDS", 15, 3);
    let mut cfg = ExperimentConfig::default();
    cfg.model = "cnn".into();
    cfg.quant.scheme = Scheme::Dsgd;
    cfg.rounds = rounds;
    cfg.train_size = 2048;
    cfg.test_size = 512;

    let backend = make_backend(&cfg)?;
    let mut coord = Coordinator::new(cfg.clone(), backend.as_ref())?;
    let spec = coord.model_spec().clone();
    section(&format!("harvesting gradients: {} rounds of uncompressed CNN training", rounds));
    for _ in 0..rounds {
        coord.step()?;
    }
    let grads = coord.last_aggregate().to_vec();

    for group in &spec.groups {
        let xs = &grads[group.start..group.end];
        section(&format!("Fig. 1 — layer group `{}` ({} params)", group.group, xs.len()));

        let pl = fit_power_law(xs).expect("power-law fit");
        let ga = fit_gaussian(xs);
        let la = fit_laplace(xs);
        let sigma = ga.params[1];

        let mut fits = Table::new(&["family", "fit", "KS"]);
        fits.row(&[
            "power-law".into(),
            format!("γ̂={:.2} ĝ_min={:.2e} ρ̂={:.3}", pl.params[0], pl.params[1], pl.params[2]),
            format!("{:.4}", pl.ks),
        ]);
        fits.row(&["gaussian".into(), format!("σ={sigma:.3e}"), format!("{:.4}", ga.ks)]);
        fits.row(&["laplace".into(), format!("b={:.3e}", la.params[1]), format!("{:.4}", la.ks)]);
        fits.print();
        report.table(&format!("fits — {}", group.group), &fits);

        let mut hist = LogHistogram::new(sigma * 0.2, sigma * 40.0, 10);
        hist.extend(xs);
        let m = report_to_model(&pl);
        let mut dens =
            Table::new(&["|g|/σ", "empirical", "power-law", "gaussian", "laplace", "emp/gauss"]);
        for (center, d) in hist.density() {
            if d == 0.0 {
                continue;
            }
            let p_pl = 2.0 * m.pdf(center);
            let p_ga = 2.0 * (-0.5 * (center / sigma).powi(2)).exp()
                / (sigma * (2.0 * std::f64::consts::PI).sqrt());
            let p_la = (-(center / la.params[1]).abs()).exp() / la.params[1];
            dens.row(&[
                format!("{:.1}", center / sigma),
                format!("{d:.2e}"),
                format!("{p_pl:.2e}"),
                format!("{p_ga:.2e}"),
                format!("{p_la:.2e}"),
                format!("{:.1e}x", d / p_ga.max(1e-300)),
            ]);
        }
        dens.print();
        report.table(&format!("density — {}", group.group), &dens);

        // The paper's headline comparison, as tail-mass ratios.
        let t = 6.0 * sigma;
        let emp = xs.iter().filter(|&&x| (x as f64).abs() > t).count() as f64 / xs.len() as f64;
        let p_ga = 2.0 * (1.0 - normal_cdf(t, ga.params[0], sigma));
        let p_la = 2.0 * (1.0 - laplace_cdf(t, la.params[0], la.params[1]));
        let p_pl = 2.0 * m.rho * (t / m.g_min).powf(1.0 - m.gamma);
        println!(
            "\nP(|g| > 6σ): empirical {emp:.2e} | power-law {p_pl:.2e} | gaussian {p_ga:.2e} | laplace {p_la:.2e}"
        );
        println!(
            "paper claim check: gaussian underestimates by {:.1e}x, laplace by {:.1e}x, power-law within {:.1}x",
            emp / p_ga.max(1e-300),
            emp / p_la.max(1e-300),
            (emp / p_pl.max(1e-300)).max(p_pl / emp.max(1e-300))
        );
    }
    report.finish(&opts)?;
    Ok(())
}
