//! §Perf — server-side aggregation throughput (the stage-4 hot path):
//!
//! * fused decode-accumulate ([`accumulate_serial`]) vs the pre-PR two-pass
//!   reference (decode into a dense scratch, then re-read it into the
//!   weighted accumulate) per payload kind — the win the committed
//!   `server_agg_fused_melems_per_s` baseline floor records,
//! * sharded aggregation scaling: Melems/s decoded+accumulated vs client
//!   count × shard count, with bit-identity to the serial result asserted
//!   on every configuration.
//!
//! Regenerate with `cargo bench --bench perf_server`; CI runs `-- --quick`
//! with `TQSGD_BENCH_JSON=BENCH_perf_server.json` and gates
//! `server_agg_fused_melems_per_s` against `BENCH_baseline.json`
//! (`tqsgd perf-check`). Refresh the baseline with
//! `TQSGD_BENCH_JSON=BENCH_baseline.json cargo bench --bench perf_server -- --quick`
//! (merge the metrics into the committed file; it also carries the encode
//! floor from `perf_hotpath`).

use tqsgd::benchkit::{bench, section, BenchOpts, Report, Table};
use tqsgd::config::{QuantConfig, Scheme};
use tqsgd::coordinator::aggregate::{
    accumulate_serial, accumulate_sharded, ContributionData, WeightedContribution,
};
use tqsgd::quant::{make_compressor, wire};
use tqsgd::runtime::GroupRange;
use tqsgd::util::Rng;

/// The pre-PR stage-4 server loop, kept verbatim as the regression
/// reference: dequantize every uplink frame into a reused dense scratch,
/// then a second pass re-reads the scratch into the weighted accumulate.
fn legacy_aggregate(
    groups: &[GroupRange],
    items: &[WeightedContribution<'_>],
    agg: &mut [f32],
    scratch: &mut Vec<f32>,
) {
    agg.fill(0.0);
    for item in items {
        let ContributionData::Frames(frames) = &item.data else {
            unreachable!("this bench only builds frame contributions")
        };
        for (gi, frame) in *frames {
            let g = &groups[*gi];
            wire::decode_dequantize_into(frame, scratch).unwrap();
            assert_eq!(scratch.len(), g.end - g.start, "frame length != group size");
            for (a, &d) in agg[g.start..g.end].iter_mut().zip(scratch.iter()) {
                *a += item.w * d;
            }
        }
    }
}

/// Frame-backed contributions in apply order (the shape `finish_round`
/// hands to the accumulate functions).
fn frame_items<'a>(
    frames: &'a [Vec<(usize, Vec<u8>)>],
    ws: &[f32],
) -> Vec<WeightedContribution<'a>> {
    frames
        .iter()
        .zip(ws)
        .map(|(f, &w)| WeightedContribution { data: ContributionData::Frames(f.as_slice()), w })
        .collect()
}

/// Per-client frame sets: one codec per layer group (refit on that group's
/// heavy-tailed draw), one compressed frame per (client, group).
fn make_frames(
    groups: &[GroupRange],
    clients: usize,
    scheme: Scheme,
    bits: u32,
    rng: &mut Rng,
) -> Vec<Vec<(usize, Vec<u8>)>> {
    let grads: Vec<Vec<f32>> = groups
        .iter()
        .map(|g| {
            (0..g.end - g.start)
                .map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32)
                .collect()
        })
        .collect();
    let mut codecs: Vec<_> = groups
        .iter()
        .map(|_| make_compressor(&QuantConfig { scheme, bits, ..Default::default() }))
        .collect();
    for (c, g) in codecs.iter_mut().zip(&grads) {
        c.refit(g);
    }
    (0..clients)
        .map(|ci| {
            codecs
                .iter_mut()
                .enumerate()
                .map(|(gi, c)| {
                    let mut r = Rng::new(0xC0DE + ci as u64 * 131 + gi as u64);
                    (gi, c.compress(&grads[gi], &mut r))
                })
                .collect()
        })
        .collect()
}

/// Normalized aggregation weights with one stale-decayed straggler, so the
/// weighted (non-uniform w) path is what gets measured.
fn weights(n: usize) -> Vec<f32> {
    let mut raw: Vec<f64> = vec![1.0 / n as f64; n];
    raw[n - 1] *= 0.5;
    let total: f64 = raw.iter().sum();
    raw.iter().map(|w| (w / total) as f32).collect()
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().map(|x| x.to_bits()).eq(b.iter().map(|x| x.to_bits()))
}

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::from_env_and_args();
    let mut report = Report::new("perf_server", &opts);
    let (warmup, runs) = if opts.quick { (1, 4) } else { (2, 8) };
    let n_groups = 8usize;
    let group_elems = opts.size("TQSGD_BENCH_GROUP_ELEMS", 131_072, 32_768);
    let d_total = n_groups * group_elems;
    let groups: Vec<GroupRange> = (0..n_groups)
        .map(|i| GroupRange {
            group: format!("g{i}"),
            start: i * group_elems,
            end: (i + 1) * group_elems,
        })
        .collect();
    let mut rng = Rng::new(7);

    section(&format!(
        "fused decode-accumulate vs pre-PR two-pass (serial, N=8, {d_total} elems/client)"
    ));
    let mut t = Table::new(&["codec", "two-pass (scratch)", "fused", "speedup", "Melems/s fused"]);
    for (scheme, bits, label) in [
        (Scheme::Tqsgd, 4u32, "tqsgd b4 (uniform)"),
        (Scheme::Tnqsgd, 3, "tnqsgd b3 (codebook)"),
        (Scheme::Dsgd, 32, "dsgd (raw fp32)"),
    ] {
        let frames = make_frames(&groups, 8, scheme, bits.min(8), &mut rng);
        let ws = weights(8);
        let items = frame_items(&frames, &ws);
        let mut agg_legacy = vec![0.0f32; d_total];
        let mut scratch = Vec::new();
        let t_legacy = bench(warmup, runs, || {
            legacy_aggregate(&groups, &items, &mut agg_legacy, &mut scratch);
            std::hint::black_box(&agg_legacy);
        });
        let mut agg_fused = vec![0.0f32; d_total];
        let t_fused = bench(warmup, runs, || {
            accumulate_serial(&groups, &items, &mut agg_fused).unwrap();
            std::hint::black_box(&agg_fused);
        });
        assert!(
            bits_eq(&agg_legacy, &agg_fused),
            "{label}: fused aggregate diverged from the two-pass reference"
        );
        let decoded = 8 * d_total;
        t.row(&[
            label.to_string(),
            t_legacy.pretty(),
            t_fused.pretty(),
            format!("{:.2}x", t_legacy.median_ns / t_fused.median_ns),
            format!("{:.1}", t_fused.melems_per_s(decoded)),
        ]);
        if scheme == Scheme::Tnqsgd {
            report.metric("server_agg_legacy_melems_per_s", t_legacy.melems_per_s(decoded));
            report.metric("server_agg_fused_melems_per_s", t_fused.melems_per_s(decoded));
            report.metric(
                "server_agg_fused_speedup_vs_legacy",
                t_legacy.median_ns / t_fused.median_ns,
            );
        }
    }
    t.print();
    report.table("fused vs two-pass serial aggregation", &t);

    section("sharded aggregation scaling (tnqsgd b3, bit-identity asserted per config)");
    let client_counts: Vec<usize> = if opts.quick { vec![8] } else { vec![4, 8, 32] };
    let shard_counts: Vec<usize> = vec![1, 2, 4, 8];
    let mut t = Table::new(&["clients", "shards", "time", "Melems/s", "speedup vs 1 shard"]);
    let mut best = 0.0f64;
    for &n in &client_counts {
        let frames = make_frames(&groups, n, Scheme::Tnqsgd, 3, &mut rng);
        let ws = weights(n);
        let items = frame_items(&frames, &ws);
        let mut agg_ref = vec![0.0f32; d_total];
        accumulate_serial(&groups, &items, &mut agg_ref)?;
        let mut base_ns = 0.0f64;
        for &shards in &shard_counts {
            let mut agg = vec![0.0f32; d_total];
            let timing = bench(warmup, runs, || {
                accumulate_sharded(&groups, &items, &mut agg, shards).unwrap();
                std::hint::black_box(&agg);
            });
            assert!(
                bits_eq(&agg, &agg_ref),
                "N={n} shards={shards}: sharded aggregate is not bit-identical to serial"
            );
            if shards == 1 {
                base_ns = timing.median_ns;
            }
            let mel = timing.melems_per_s(n * d_total);
            best = best.max(mel);
            t.row(&[
                n.to_string(),
                shards.to_string(),
                timing.pretty(),
                format!("{mel:.1}"),
                format!("{:.2}x", base_ns / timing.median_ns),
            ]);
        }
    }
    t.print();
    report.table("sharded aggregation scaling", &t);
    report.metric("server_agg_sharded_best_melems_per_s", best);

    report.finish(&opts)?;
    Ok(())
}
