//! §Perf — hot-path performance of the whole stack:
//!
//! * L3 codec throughput per scheme/bits, with before/after columns for the
//!   allocating `compress` wrapper vs the arena-reuse `compress_into` path,
//! * the pre-PR encode reference (fused-but-allocating with per-byte RMW
//!   bit-packing) vs the streaming-accumulator kernel — the ≥2× claim the
//!   committed `BENCH_baseline.json` records,
//! * decode + aggregate throughput (`decode_dequantize` vs the `_into`
//!   scratch-reuse variant),
//! * L1↔L3 parity + relative cost of running the quantizer kernel through
//!   the backend's `QuantKernel` interface,
//! * end-to-end round breakdown for the CNN config, including the
//!   steady-state frame-allocation counter (must stay flat).
//!
//! Regenerate with `cargo bench --bench perf_hotpath`; CI runs
//! `-- --quick` with `TQSGD_BENCH_JSON=BENCH_perf.json` and gates the
//! `tqsgd_b4_encode_into_melems_per_s` metric against
//! `BENCH_baseline.json` (`tqsgd perf-check`). Refresh the baseline with
//! `TQSGD_BENCH_JSON=BENCH_baseline.json cargo bench --bench perf_hotpath -- --quick`.

use tqsgd::benchkit::{bench, fmt_ns, section, BenchOpts, Report, Table};
use tqsgd::config::{ExperimentConfig, QuantConfig, Scheme};
use tqsgd::coordinator::Coordinator;
use tqsgd::quant::{bitpack, make_compressor, wire};
use tqsgd::runtime::backend_for;
use tqsgd::util::Rng;

/// The pre-PR uniform encode path, kept verbatim as the regression
/// reference: fused quantize+pack into a freshly allocated, pre-zeroed
/// packed buffer with per-byte read-modify-write stores and a `floor()`
/// call per element, then a second allocation + copy to assemble the frame.
fn legacy_compress_uniform(
    grads: &[f32],
    rng: &mut Rng,
    alpha: f32,
    s: u32,
    bits: u32,
) -> Vec<u8> {
    let mut packed = vec![0u8; bitpack::packed_len(grads.len(), bits)];
    let step = 2.0f32 * alpha / s as f32;
    let inv_step = 1.0f32 / step;
    let s_m1 = (s - 1) as f32;
    let s_f = s as f32;
    let mut bitpos = 0usize;
    for &g in grads {
        let u = rng.f32();
        let gc = g.clamp(-alpha, alpha);
        let x = (gc + alpha) * inv_step;
        let lo = x.floor().min(s_m1).max(0.0);
        let idx = (lo + f32::from(u < x - lo)).min(s_f) as u32;
        let byte = bitpos >> 3;
        let off = (bitpos & 7) as u32;
        let wide = (idx as u16) << off;
        packed[byte] |= (wide & 0xFF) as u8;
        if wide > 0xFF {
            packed[byte + 1] |= (wide >> 8) as u8;
        }
        bitpos += bits as usize;
    }
    wire::encode_uniform_packed(alpha, s as u16, grads.len() as u32, bits, &packed)
}

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::from_env_and_args();
    let mut report = Report::new("perf_hotpath", &opts);
    let (warmup, runs) = if opts.quick { (1, 4) } else { (2, 8) };
    let mut rng = Rng::new(99);
    let d = 1 << 20; // 1M elements, CNN-to-MLP scale (also in quick mode)
    let grads: Vec<f32> =
        (0..d).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();

    section("L3 codec throughput (1M elements, single core)");
    println!("(compress = allocating wrapper; compress_into = recycled arena buffer)");
    let mut t = Table::new(&[
        "codec",
        "bits",
        "compress",
        "compress_into",
        "speedup",
        "GB/s in",
        "bytes out",
    ]);
    for (scheme, bits) in [
        (Scheme::Dsgd, 32u32),
        (Scheme::Qsgd, 3),
        (Scheme::Tqsgd, 2),
        (Scheme::Tqsgd, 3),
        (Scheme::Tqsgd, 4),
        (Scheme::Tqsgd, 5),
        (Scheme::Tnqsgd, 3),
        (Scheme::Tnqsgd, 5),
        (Scheme::Tbqsgd, 3),
        (Scheme::Terngrad, 2),
        (Scheme::Topk, 32),
    ] {
        let mut c = make_compressor(&QuantConfig {
            scheme,
            bits: bits.min(8),
            ..Default::default()
        });
        c.refit(&grads);
        let mut out_len = 0usize;
        let t_alloc = bench(warmup, runs, || {
            let mut r = Rng::new(1);
            let frame = c.compress(&grads, &mut r);
            out_len = frame.len();
            std::hint::black_box(&frame);
        });
        let mut buf = Vec::new();
        let t_into = bench(warmup, runs, || {
            let mut r = Rng::new(1);
            c.compress_into(&grads, &mut r, &mut buf);
            std::hint::black_box(&buf);
        });
        t.row(&[
            c.describe(),
            bits.to_string(),
            t_alloc.pretty(),
            t_into.pretty(),
            format!("{:.2}x", t_alloc.median_ns / t_into.median_ns),
            format!("{:.2}", t_into.gbps(d * 4)),
            out_len.to_string(),
        ]);
        if scheme == Scheme::Tqsgd && bits == 4 {
            report.metric("tqsgd_b4_encode_melems_per_s", t_alloc.melems_per_s(d));
            report.metric("tqsgd_b4_encode_into_melems_per_s", t_into.melems_per_s(d));
        }
    }
    t.print();
    report.table("L3 codec throughput (1M elements)", &t);

    section("pre-PR reference vs compress_into (4-bit TQSGD, 1M elements)");
    // Identical alpha for both paths so the comparison is pure code-path:
    // pre-PR = floor() + RMW pack + zeroed packed buffer + frame copy.
    let alpha = 0.05f32;
    let t_legacy = bench(warmup, runs, || {
        let mut r = Rng::new(1);
        std::hint::black_box(legacy_compress_uniform(&grads, &mut r, alpha, 15, 4));
    });
    let mut buf = Vec::new();
    let t_new = bench(warmup, runs, || {
        let mut r = Rng::new(1);
        wire::begin_uniform_frame(&mut buf, alpha, 15, grads.len() as u32, 4);
        tqsgd::quant::kernels::quantize_uniform_pack_into(&grads, &mut r, alpha, 15, 4, &mut buf);
        std::hint::black_box(&buf);
    });
    // Sanity: the two paths are byte-identical given the same RNG stream
    // (`buf` holds the last measured run, which used Rng::new(1) too).
    let mut r1 = Rng::new(1);
    let legacy_frame = legacy_compress_uniform(&grads, &mut r1, alpha, 15, 4);
    assert_eq!(legacy_frame, buf, "legacy and fused frames must agree");
    let speedup = t_legacy.median_ns / t_new.median_ns;
    println!(
        "pre-PR {} vs compress_into {} → {:.2}x single-core encode speedup",
        t_legacy.pretty(),
        t_new.pretty(),
        speedup
    );
    report.metric("tqsgd_b4_legacy_melems_per_s", t_legacy.melems_per_s(d));
    report.metric("tqsgd_b4_speedup_vs_legacy", speedup);

    section("decode + aggregate throughput");
    let mut t = Table::new(&["codec", "decode+dequant", "decode_into (reused)", "GB/s out"]);
    for scheme in [Scheme::Tqsgd, Scheme::Tnqsgd] {
        let mut c = make_compressor(&QuantConfig { scheme, bits: 3, ..Default::default() });
        c.refit(&grads);
        let frame = c.compress(&grads, &mut rng);
        let t_alloc = bench(warmup, runs, || {
            let v = wire::decode_dequantize(&frame).unwrap();
            std::hint::black_box(&v);
        });
        let mut dense = Vec::new();
        let t_into = bench(warmup, runs, || {
            wire::decode_dequantize_into(&frame, &mut dense).unwrap();
            std::hint::black_box(&dense);
        });
        t.row(&[
            c.describe(),
            t_alloc.pretty(),
            t_into.pretty(),
            format!("{:.2}", t_into.gbps(d * 4)),
        ]);
        if scheme == Scheme::Tqsgd {
            report.metric("tqsgd_b3_decode_into_melems_per_s", t_into.melems_per_s(d));
        }
    }
    t.print();
    report.table("decode + aggregate throughput", &t);

    section("L1 quantizer kernel via Backend::quant_kernel (parity + cost)");
    // Auto-select, but degrade gracefully (e.g. pjrt feature + artifacts
    // present but only the xla stub linked) instead of aborting the bench.
    let backend = backend_for("auto", "artifacts").unwrap_or_else(|e| {
        println!("(auto backend unavailable: {e}; falling back to native)");
        backend_for("native", "artifacts").expect("native backend is always available")
    });
    println!("backend: {}", backend.name());
    let q = backend.quant_kernel("quant_uniform_b3")?;
    let tile = q.tile().min(grads.len());
    let g = &grads[..tile];
    let u: Vec<f32> = (0..tile).map(|_| rng.f32()).collect();
    let kalpha = 0.05f32;
    let (_deq, idx) = q.run_uniform(g, &u, kalpha)?;
    // Parity: rust codec must produce identical indices.
    let mut rust_idx = Vec::new();
    tqsgd::quant::kernels::quantize_uniform_slice(g, &u, kalpha, 7, &mut rust_idx);
    let mismatches = idx.iter().zip(&rust_idx).filter(|(a, b)| a != b).count();
    println!("parity quant_uniform_b3 vs rust codec: {mismatches}/{tile} index mismatches");
    let mut deq_buf = Vec::new();
    let mut idx_buf = Vec::new();
    let timing = bench(1, if opts.quick { 3 } else { 5 }, || {
        q.run_uniform_into(g, &u, kalpha, &mut deq_buf, &mut idx_buf).unwrap();
        std::hint::black_box((&deq_buf, &idx_buf));
    });
    println!(
        "kernel tile ({tile} elems, run_uniform_into): {} ({:.3} GB/s)",
        timing.pretty(),
        timing.gbps(tile * 4)
    );

    section("end-to-end round breakdown (CNN, N=8, b=3)");
    let mut cfg = ExperimentConfig::default();
    cfg.model = "cnn".into();
    cfg.rounds = 4;
    cfg.train_size = if opts.quick { 1024 } else { 2048 };
    cfg.test_size = 512;
    cfg.quant.scheme = Scheme::Tnqsgd;
    let mut coord = Coordinator::new(cfg, backend.as_ref())?;
    coord.step()?; // warm caches (executables on PJRT, arenas on native)
    coord.step()?;
    let allocs_before = coord.frame_allocs();
    let timing = bench(0, if opts.quick { 2 } else { 6 }, || {
        coord.step().unwrap();
    });
    let allocs_after = coord.frame_allocs();
    println!("full round: {}", fmt_ns(timing.median_ns));
    println!(
        "frame allocations during measured rounds: {} (steady state must be 0; warm-up total {})",
        allocs_after - allocs_before,
        allocs_before
    );
    report.metric(
        "steady_state_frame_allocs",
        (allocs_after - allocs_before) as f64,
    );

    // Isolate codec share: same gradient size, 8 clients, 2 groups.
    let spec = coord.model_spec().clone();
    let per_client: Vec<f32> = grads[..spec.param_count].to_vec();
    let mut c = make_compressor(&QuantConfig {
        scheme: Scheme::Tnqsgd,
        bits: 3,
        ..Default::default()
    });
    c.refit(&per_client);
    let mut cbuf = Vec::new();
    let codec_t = bench(1, if opts.quick { 3 } else { 6 }, || {
        for cl in 0..8 {
            let mut r = Rng::new(cl);
            c.compress_into(&per_client, &mut r, &mut cbuf);
            std::hint::black_box(&cbuf);
        }
    });
    println!(
        "8-client codec work (serial): {} → {:.1}% of round (threads hide most of it)",
        fmt_ns(codec_t.median_ns),
        100.0 * codec_t.median_ns / timing.median_ns
    );

    report.finish(&opts)?;
    Ok(())
}
