//! §Perf — hot-path performance of the whole stack:
//!
//! * L3 codec throughput (encode+pack GB/s per scheme/bits; target ≥1 GB/s
//!   for 4-bit uniform on one core),
//! * bit-packing substrate throughput,
//! * L1↔L3 parity + relative cost of running the quantizer kernel through
//!   the backend's `QuantKernel` interface (native scalar kernels by
//!   default; the Pallas/PJRT artifact when built with `--features pjrt`),
//! * end-to-end round breakdown (grad exec vs codec vs aggregate) for the
//!   CNN config, showing the coordinator is not the bottleneck.
//!
//! Regenerate with `cargo bench --bench perf_hotpath`.

use tqsgd::benchkit::{bench, fmt_ns, section, Table};
use tqsgd::config::{ExperimentConfig, QuantConfig, Scheme};
use tqsgd::coordinator::Coordinator;
use tqsgd::quant::{make_compressor, Payload};
use tqsgd::runtime::backend_for;
use tqsgd::util::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(99);
    let d = 1 << 20; // 1M elements, CNN-to-MLP scale
    let grads: Vec<f32> =
        (0..d).map(|_| rng.power_law_gradient(0.01, 4.0, 0.2) as f32).collect();

    section("L3 codec throughput (1M elements, single core)");
    let mut t = Table::new(&["codec", "bits", "encode", "GB/s in", "bytes out"]);
    for (scheme, bits) in [
        (Scheme::Dsgd, 32u32),
        (Scheme::Qsgd, 3),
        (Scheme::Tqsgd, 2),
        (Scheme::Tqsgd, 3),
        (Scheme::Tqsgd, 4),
        (Scheme::Tqsgd, 5),
        (Scheme::Tnqsgd, 3),
        (Scheme::Tnqsgd, 5),
        (Scheme::Tbqsgd, 3),
        (Scheme::Terngrad, 2),
        (Scheme::Topk, 32),
    ] {
        let mut c = make_compressor(&QuantConfig {
            scheme,
            bits: bits.min(8),
            ..Default::default()
        });
        c.refit(&grads);
        let mut out_len = 0usize;
        let timing = bench(2, 8, || {
            let mut r = Rng::new(1);
            let frame = c.compress(&grads, &mut r);
            out_len = frame.len();
            std::hint::black_box(&frame);
        });
        t.row(&[
            c.describe(),
            bits.to_string(),
            timing.pretty(),
            format!("{:.2}", timing.gbps(d * 4)),
            out_len.to_string(),
        ]);
    }
    t.print();

    section("decode + aggregate throughput");
    let mut t = Table::new(&["codec", "decode+dequant", "GB/s out"]);
    for scheme in [Scheme::Tqsgd, Scheme::Tnqsgd] {
        let mut c = make_compressor(&QuantConfig { scheme, bits: 3, ..Default::default() });
        c.refit(&grads);
        let frame = c.compress(&grads, &mut rng);
        let timing = bench(2, 8, || {
            let v = Payload::decode(&frame).unwrap().dequantize();
            std::hint::black_box(&v);
        });
        t.row(&[
            c.describe(),
            timing.pretty(),
            format!("{:.2}", timing.gbps(d * 4)),
        ]);
    }
    t.print();

    section("L1 quantizer kernel via Backend::quant_kernel (parity + cost)");
    // Auto-select, but degrade gracefully (e.g. pjrt feature + artifacts
    // present but only the xla stub linked) instead of aborting the bench.
    let backend = backend_for("auto", "artifacts").unwrap_or_else(|e| {
        println!("(auto backend unavailable: {e}; falling back to native)");
        backend_for("native", "artifacts").expect("native backend is always available")
    });
    println!("backend: {}", backend.name());
    let q = backend.quant_kernel("quant_uniform_b3")?;
    let tile = q.tile().min(grads.len());
    let g = &grads[..tile];
    let u: Vec<f32> = (0..tile).map(|_| rng.f32()).collect();
    let alpha = 0.05f32;
    let (_deq, idx) = q.run_uniform(g, &u, alpha)?;
    // Parity: rust codec must produce identical indices.
    let mut rust_idx = Vec::new();
    tqsgd::quant::kernels::quantize_uniform_slice(g, &u, alpha, 7, &mut rust_idx);
    let mismatches = idx.iter().zip(&rust_idx).filter(|(a, b)| a != b).count();
    println!("parity quant_uniform_b3 vs rust codec: {mismatches}/{tile} index mismatches");
    let timing = bench(1, 5, || {
        let r = q.run_uniform(g, &u, alpha).unwrap();
        std::hint::black_box(&r);
    });
    println!(
        "kernel tile ({tile} elems): {} ({:.3} GB/s)",
        timing.pretty(),
        timing.gbps(tile * 4)
    );

    section("end-to-end round breakdown (CNN, N=8, b=3)");
    let mut cfg = ExperimentConfig::default();
    cfg.model = "cnn".into();
    cfg.rounds = 4;
    cfg.train_size = 2048;
    cfg.test_size = 512;
    cfg.quant.scheme = Scheme::Tnqsgd;
    let mut coord = Coordinator::new(cfg, backend.as_ref())?;
    coord.step()?; // warm caches (executables on PJRT, allocators on native)
    let timing = bench(1, 6, || {
        coord.step().unwrap();
    });
    println!("full round: {}", fmt_ns(timing.median_ns));

    // Isolate codec share: same gradient size, 8 clients, 2 groups.
    let spec = coord.model_spec().clone();
    let per_client: Vec<f32> = grads[..spec.param_count].to_vec();
    let mut c = make_compressor(&QuantConfig {
        scheme: Scheme::Tnqsgd,
        bits: 3,
        ..Default::default()
    });
    c.refit(&per_client);
    let codec_t = bench(1, 6, || {
        for cl in 0..8 {
            let mut r = Rng::new(cl);
            std::hint::black_box(c.compress(&per_client, &mut r));
        }
    });
    println!(
        "8-client codec work (serial): {} → {:.1}% of round (threads hide most of it)",
        fmt_ns(codec_t.median_ns),
        100.0 * codec_t.median_ns / timing.median_ns
    );
    Ok(())
}
