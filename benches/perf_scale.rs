//! §Perf — million-client-round scale machinery: the two-tier aggregator
//! tree (`coordinator::aggregate::accumulate_two_tier`) and the cohort
//! sampling + parked-residual memory path.
//!
//! * mid-tier decode→re-encode→fuse throughput over synthetic dense
//!   contributions (`tier_agg_melems_per_s`), with a flat-path reference
//!   column so the tree's overhead is visible;
//! * cohort-round memory footprint: `bytes_per_client` after a short
//!   error-feedback run with an engaged cohort, reported raw and inverted
//!   as `cohort_clients_per_mib` (clients a mid-tier node can park per MiB
//!   — higher is better, which is what `tqsgd perf-check` gates);
//! * a cohort K=N bit-identity spot check, mirroring the degraded-mode
//!   checks in `perf_round` — the timed machinery must not drift from the
//!   full-participation reference.
//!
//! Regenerate with `cargo bench --bench perf_scale`; CI runs `-- --quick`
//! with `TQSGD_BENCH_JSON=BENCH_perf_scale.json` and gates
//! `tier_agg_melems_per_s` + `cohort_clients_per_mib` against
//! `BENCH_baseline.json` (`tqsgd perf-check`). Refresh the baseline on real
//! hardware with
//! `TQSGD_BENCH_JSON=BENCH_perf_scale.json cargo bench --bench perf_scale -- --quick`
//! and merge the metrics into the committed file.

use tqsgd::benchkit::{bench, section, BenchOpts, Report, Table};
use tqsgd::config::{ExperimentConfig, Scheme};
use tqsgd::coordinator::aggregate::{
    accumulate_sharded, accumulate_two_tier, ContributionData, WeightedContribution,
};
use tqsgd::coordinator::Coordinator;
use tqsgd::metrics::RunLog;
use tqsgd::runtime::{backend_for, GroupRange};

/// Synthetic aggregation workload: `items` dense contributions over `dim`
/// elements split into `ngroups` equal layer groups.
fn synthetic(dim: usize, ngroups: usize, items: usize) -> (Vec<GroupRange>, Vec<Vec<f32>>) {
    let per = dim / ngroups;
    let groups = (0..ngroups)
        .map(|g| GroupRange { group: format!("g{g}"), start: g * per, end: (g + 1) * per })
        .collect();
    let dense = (0..items)
        .map(|j| (0..dim).map(|e| ((j * 31 + e) % 97) as f32 * 0.02 - 0.96).collect())
        .collect();
    (groups, dense)
}

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::from_env_and_args();
    let mut report = Report::new("perf_scale", &opts);
    let backend = backend_for("native", "unused")?;
    let (warmup, runs) = if opts.quick { (2, 8) } else { (4, 24) };

    // -- Cohort K=N bit-identity spot check (cheap, always run) ------------
    section("cohort K=N vs disabled-cohort bit-identity spot check");
    {
        let mut cfg = ExperimentConfig::default();
        cfg.model = "mlp_tiny".into();
        cfg.backend = "native".into();
        cfg.quant.scheme = Scheme::Tnqsgd;
        cfg.quant.bits = 3;
        cfg.clients = 4;
        cfg.train_size = 384;
        cfg.test_size = 96;
        cfg.seed = 11;
        let digest = |cfg: &ExperimentConfig| -> anyhow::Result<String> {
            let mut coord = Coordinator::new(cfg.clone(), backend.as_ref())?;
            let mut log = RunLog::default();
            for _ in 0..3 {
                log.push(coord.step()?);
            }
            Ok(log.replay_digest())
        };
        let reference = digest(&cfg)?;
        cfg.cohort_k = cfg.clients;
        assert_eq!(reference, digest(&cfg)?, "cohort K=N must be bit-identical to disabled");
        println!("  K=N: digest bit-identical to full participation over 3 rounds");
    }

    // -- Two-tier aggregation throughput -----------------------------------
    let (dim, ngroups, items) = if opts.quick { (65_536, 8, 36) } else { (262_144, 8, 64) };
    section(&format!(
        "two-tier aggregation throughput (dim {dim}, {ngroups} groups, {items} contributions)"
    ));
    let (groups, dense) = synthetic(dim, ngroups, items);
    let contribs: Vec<WeightedContribution<'_>> = dense
        .iter()
        .map(|d| WeightedContribution {
            data: ContributionData::Dense(&d[..]),
            w: 1.0 / items as f32,
        })
        .collect();
    let quant = {
        let mut q = ExperimentConfig::default().quant;
        q.scheme = Scheme::Qsgd;
        q.bits = 4;
        q
    };
    let shards = 4usize;
    let elems = dim * items;
    let mut agg = vec![0.0f32; dim];
    let mut t = Table::new(&["path", "call", "Melems/s", "tier bytes"]);

    let flat = bench(warmup, runs, || {
        accumulate_sharded(&groups, &contribs, &mut agg, shards).expect("flat aggregate");
    });
    t.row(&[
        "flat (reference)".into(),
        flat.pretty(),
        format!("{:.1}", flat.melems_per_s(elems)),
        "0".into(),
    ]);

    let mut round = 0u64;
    let mut tier_bytes = 0u64;
    let tiered = bench(warmup, runs, || {
        tier_bytes =
            accumulate_two_tier(&groups, &contribs, &mut agg, shards, &quant, 7, round)
                .expect("two-tier aggregate");
        round += 1;
    });
    t.row(&[
        "two-tier (qsgd b4)".into(),
        tiered.pretty(),
        format!("{:.1}", tiered.melems_per_s(elems)),
        tier_bytes.to_string(),
    ]);
    assert!(tier_bytes > 0, "the tree must have re-encoded mid-tier partial sums");
    t.print();
    report.metric("tier_agg_melems_per_s", tiered.melems_per_s(elems));
    report.metric("tier_agg_flat_ratio", tiered.melems_per_s(elems) / flat.melems_per_s(elems));
    report.table("two-tier aggregation throughput", &t);

    // -- Cohort-round memory footprint --------------------------------------
    section("cohort-round per-client memory (mlp, N=8, K=3, error feedback)");
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp".into();
    cfg.backend = "native".into();
    cfg.quant.scheme = Scheme::Tqsgd;
    cfg.quant.bits = 3;
    cfg.quant.error_feedback = true;
    cfg.clients = 8;
    cfg.cohort_k = 3;
    cfg.agg_tiers = 2;
    cfg.train_size = 2048;
    cfg.test_size = 256;
    cfg.seed = 7;
    let mut coord = Coordinator::new(cfg, backend.as_ref())?;
    let mut bytes_per_client = 0u64;
    for _ in 0..4 {
        bytes_per_client = coord.step()?.bytes_per_client;
    }
    assert!(bytes_per_client > 0, "memory metric must be recorded");
    let clients_per_mib = (1u64 << 20) as f64 / bytes_per_client as f64;
    let mut m = Table::new(&["metric", "value"]);
    m.row(&["bytes_per_client".into(), bytes_per_client.to_string()]);
    m.row(&["cohort_clients_per_mib".into(), format!("{clients_per_mib:.2}")]);
    m.row(&["tier_uplink_bytes (4 rounds)".into(), coord.tier_uplink_bytes().to_string()]);
    m.print();
    report.metric("bytes_per_client", bytes_per_client as f64);
    report.metric("cohort_clients_per_mib", clients_per_mib);
    report.table("cohort-round per-client memory", &m);

    report.finish(&opts)?;
    Ok(())
}
