//! §Perf — end-to-end round throughput through the coordinator's round
//! engine: the strict-barrier reference vs the streaming pipeline that
//! overlaps client encode with server decode (`coordinator/pipeline.rs`).
//!
//! * barrier vs streaming Melems/s over full rounds (compute + encode +
//!   uplink + schedule + weighted apply) with **bit-identity asserted per
//!   configuration** — the two timed runs must land on identical parameters
//!   and `replay_digest()`s, and short stale/churn runs re-check the
//!   degraded-mode paths;
//! * the per-stage wall-clock breakdown (`compute/encode/agg` columns of
//!   `RoundRecord`) so the encode↔decode overlap is visible, not inferred;
//! * budgeted rounds (multiscale under a binding `bit_budget`), asserting
//!   the per-round uplink stays under the budget and recording
//!   `budget_round_melems_per_s` / `budget_bytes_per_round`.
//!
//! Regenerate with `cargo bench --bench perf_round`; CI runs `-- --quick`
//! with `TQSGD_BENCH_JSON=BENCH_perf_round.json` and gates
//! `round_streaming_melems_per_s` against `BENCH_baseline.json`
//! (`tqsgd perf-check`). Refresh the baseline on real hardware with
//! `TQSGD_BENCH_JSON=BENCH_perf_round.json cargo bench --bench perf_round -- --quick`
//! and merge the metric into the committed file.

use tqsgd::benchkit::{bench, section, BenchOpts, Report, Table};
use tqsgd::config::{ExperimentConfig, PipelineMode, ScenarioConfig, Scheme};
use tqsgd::coordinator::Coordinator;
use tqsgd::metrics::{RoundRecord, RunLog};
use tqsgd::runtime::{backend_for, Backend};

fn base_cfg(scheme: Scheme, bits: u32, pipeline: PipelineMode) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp".into();
    cfg.backend = "native".into();
    cfg.quant.scheme = scheme;
    cfg.quant.bits = bits;
    cfg.clients = 8;
    cfg.train_size = 2048;
    cfg.test_size = 256;
    cfg.seed = 7;
    cfg.pipeline = pipeline;
    cfg
}

/// f32 bit patterns, so the identity asserts are bitwise (`==` on f32 would
/// let a +0.0/−0.0 sign flip through — the exact hazard the dense
/// contribution path's determinism argument rules out).
fn bits_of(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn digest_of(records: Vec<RoundRecord>) -> String {
    let mut log = RunLog::default();
    for r in records {
        log.push(r);
    }
    log.replay_digest()
}

/// Run `rounds` rounds on a fresh coordinator; returns (params, digest).
fn run_rounds(
    backend: &dyn Backend,
    cfg: &ExperimentConfig,
    rounds: usize,
) -> anyhow::Result<(Vec<f32>, String)> {
    let mut coord = Coordinator::new(cfg.clone(), backend)?;
    let mut records = Vec::with_capacity(rounds);
    for _ in 0..rounds {
        records.push(coord.step()?);
    }
    Ok((coord.params.clone(), digest_of(records)))
}

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::from_env_and_args();
    let mut report = Report::new("perf_round", &opts);
    let backend = backend_for("native", "unused")?;
    let (warmup, runs) = if opts.quick { (2, 8) } else { (4, 24) };

    // -- Degraded-mode bit-identity spot checks (cheap, always run) --------
    section("streaming vs barrier bit-identity (stale + churn spot checks)");
    for preset in ["stale", "churn"] {
        let mut cfg = base_cfg(Scheme::Tnqsgd, 3, PipelineMode::Barrier);
        cfg.clients = 4;
        cfg.net.bandwidth_bytes_per_sec = 1e6;
        cfg.net.latency_sec = 0.01;
        cfg.scenario = ScenarioConfig::preset(preset)?;
        let (p_barrier, d_barrier) = run_rounds(backend.as_ref(), &cfg, 4)?;
        cfg.pipeline = PipelineMode::Streaming;
        let (p_streaming, d_streaming) = run_rounds(backend.as_ref(), &cfg, 4)?;
        assert_eq!(d_barrier, d_streaming, "{preset}: replay digests diverged");
        assert_eq!(bits_of(&p_barrier), bits_of(&p_streaming), "{preset}: parameters diverged");
        println!("  {preset}: params + digest bit-identical over 4 rounds");
    }

    // -- Timed end-to-end rounds, identity asserted on the timed runs too --
    section(&format!(
        "end-to-end round throughput, barrier vs streaming (mlp, N=8, {} timed rounds)",
        runs
    ));
    let mut t = Table::new(&[
        "codec",
        "pipeline",
        "round",
        "Melems/s",
        "compute",
        "encode(+decode)",
        "agg",
    ]);
    let codecs = [(Scheme::Tnqsgd, 3u32, "tnqsgd b3"), (Scheme::Tqsgd, 4, "tqsgd b4")];
    for (scheme, bits, label) in codecs {
        let mut outcomes: Vec<(Vec<f32>, String, f64)> = Vec::new();
        for pipeline in [PipelineMode::Barrier, PipelineMode::Streaming] {
            let cfg = base_cfg(scheme, bits, pipeline);
            let mut coord = Coordinator::new(cfg.clone(), backend.as_ref())?;
            let elems = coord.params.len() * cfg.clients;
            let mut records: Vec<RoundRecord> = Vec::with_capacity(warmup + runs);
            let timing = bench(warmup, runs, || {
                records.push(coord.step().expect("round"));
            });
            // Stage breakdown over the TIMED rounds only — the warmup
            // rounds (contrib sizing, cold caches) also ran the closure.
            let mean = |f: fn(&RoundRecord) -> f64| -> f64 {
                records.iter().skip(warmup).map(f).sum::<f64>() / runs as f64
            };
            t.row(&[
                label.to_string(),
                pipeline.name().to_string(),
                timing.pretty(),
                format!("{:.1}", timing.melems_per_s(elems)),
                format!("{:.1}ms", mean(|r| r.compute_secs) * 1e3),
                format!("{:.1}ms", mean(|r| r.encode_secs) * 1e3),
                format!("{:.1}ms", mean(|r| r.agg_secs) * 1e3),
            ]);
            if scheme == Scheme::Tnqsgd {
                report.metric(
                    &format!("round_{}_melems_per_s", pipeline.name()),
                    timing.melems_per_s(elems),
                );
            }
            outcomes.push((coord.params.clone(), digest_of(records), timing.median_ns));
        }
        let (p_barrier, d_barrier, ns_barrier) = &outcomes[0];
        let (p_streaming, d_streaming, ns_streaming) = &outcomes[1];
        assert_eq!(d_barrier, d_streaming, "{label}: timed runs' digests diverged");
        assert_eq!(bits_of(p_barrier), bits_of(p_streaming), "{label}: timed params diverged");
        if scheme == Scheme::Tnqsgd {
            report.metric("round_streaming_speedup_vs_barrier", ns_barrier / ns_streaming);
        }
    }
    t.print();
    report.table("end-to-end round throughput (barrier vs streaming)", &t);

    // -- Budgeted rounds: scheduler planning + multiscale re-rating on the
    // -- hot path, with the per-round uplink cap asserted on every timed
    // -- round (the bytes the committed `budget_bytes_per_round` gate pins).
    section(&format!(
        "budgeted round throughput (multiscale b8, streaming, {} timed rounds)",
        runs
    ));
    let mut cfg = base_cfg(Scheme::Multiscale, 8, PipelineMode::Streaming);
    // Probe one unbudgeted round for the free-running uplink, then set the
    // fleet budget to 60% of it: binding at 8 bits, comfortably above the
    // scheduler's 3-bit multiscale floor.
    let free_bytes = {
        let mut probe = Coordinator::new(cfg.clone(), backend.as_ref())?;
        probe.step()?.bytes_up
    };
    cfg.bit_budget = free_bytes * 6 / 10;
    let mut t = Table::new(&["pipeline", "round", "Melems/s", "bytes/round", "free bytes"]);
    let mut coord = Coordinator::new(cfg.clone(), backend.as_ref())?;
    let elems = coord.params.len() * cfg.clients;
    let mut records: Vec<RoundRecord> = Vec::with_capacity(warmup + runs);
    let timing = bench(warmup, runs, || {
        records.push(coord.step().expect("budgeted round"));
    });
    let max_bytes =
        records.iter().skip(warmup).map(|r| r.bytes_up).max().expect("timed rounds ran");
    assert!(
        max_bytes <= cfg.bit_budget,
        "budgeted round spent {max_bytes} bytes, over the {} budget",
        cfg.bit_budget
    );
    assert!(max_bytes < free_bytes, "the 60% budget must be binding (free = {free_bytes})");
    assert!(coord.params.iter().all(|p| p.is_finite()), "params must stay finite under budget");
    t.row(&[
        "streaming".to_string(),
        timing.pretty(),
        format!("{:.1}", timing.melems_per_s(elems)),
        max_bytes.to_string(),
        free_bytes.to_string(),
    ]);
    t.print();
    report.table("budgeted round throughput (multiscale b8)", &t);
    report.metric("budget_round_melems_per_s", timing.melems_per_s(elems));
    report.metric("budget_bytes_per_round", max_bytes as f64);

    report.finish(&opts)?;
    Ok(())
}
