//! Lemma 2 / Eq. (21) — the MSE of the two-stage quantizer decomposes into
//!
//!   quantization variance  ∫_{−α}^{α} p/(4λ²)   +   truncation bias
//!   2∫_α^∞ (g−α)² p,
//!
//! and the two terms trade off in α exactly as Sec. III-B describes: small α
//! ⇒ tiny variance, big bias; large α ⇒ the reverse.  Measured by
//! Monte-Carlo against the closed-form integrals across an α sweep.
//!
//! Regenerate with `cargo bench --bench lemma2_decomposition`.

use tqsgd::benchkit::{section, BenchOpts, Report, Table};
use tqsgd::quant::kernels::{dequantize_uniform_elem, quantize_uniform_elem};
use tqsgd::solver::optimal_alpha_uniform;
use tqsgd::tail::PowerLawModel;
use tqsgd::theory::{quantization_variance, truncation_bias};
use tqsgd::util::Rng;

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::from_env_and_args();
    let mut report = Report::new("lemma2_decomposition", &opts);
    let n = opts.size("TQSGD_BENCH_SAMPLES", 300_000, 30_000);
    let m = PowerLawModel::new(4.0, 0.01, 0.1);
    let s = 7usize;
    let mut rng = Rng::new(7);
    let grads: Vec<f32> =
        (0..n).map(|_| rng.power_law_gradient(m.g_min, m.gamma, 2.0 * m.rho) as f32).collect();

    let a_star = optimal_alpha_uniform(&m, s);
    section(&format!(
        "Lemma 2 — MSE decomposition, uniform density, s={s} (α* = {a_star:.4})"
    ));

    // The Lemma 1/2 variance term ∫ p/(4λ²) is an UPPER bound (y(1−y) ≤ 1/4
    // inside each interval); the high-rate EXACT value replaces 1/4 by 1/6
    // (appendix proof, step (a)). We print both: measured MSE must stay
    // below bound+bias and track (2/3)·bound+bias closely.
    let mut t = Table::new(&[
        "α (α*×)",
        "measured MSE",
        "var bound (Δ²/4)",
        "var exact (Δ²/6)",
        "bias",
        "exact+bias",
        "rel err",
        "≤ bound+bias",
    ]);
    for &scale in &[0.62, 0.75, 1.0, 1.5, 2.5, 4.0] {
        let alpha = (a_star * scale).max(m.g_min * 1.01);
        // Monte-Carlo MSE of Q[T[g]] vs RAW g (both stages contribute).
        let mut mse = 0.0f64;
        for &g in &grads {
            let idx = quantize_uniform_elem(g, rng.f32(), alpha as f32, s as u32);
            let q = dequantize_uniform_elem(idx, alpha as f32, s as u32);
            mse += ((q - g) as f64).powi(2);
        }
        mse /= grads.len() as f64;
        let var_bound = quantization_variance(&m, alpha, |_| s as f64 / (2.0 * alpha));
        let var_exact = var_bound * 2.0 / 3.0;
        let bias = truncation_bias(&m, alpha);
        let pred = var_exact + bias;
        t.row(&[
            format!("{alpha:.4} ({scale:.2})"),
            format!("{mse:.4e}"),
            format!("{var_bound:.4e}"),
            format!("{var_exact:.4e}"),
            format!("{bias:.4e}"),
            format!("{pred:.4e}"),
            format!("{:+.1}%", 100.0 * (mse - pred) / pred),
            (mse <= (var_bound + bias) * 1.02).to_string(),
        ]);
    }
    t.print();
    report.table("Lemma 2 — MSE decomposition (α sweep)", &t);
    println!(
        "\nshape check: variance grows with α (∝ α²), bias shrinks with α (∝ α^{{3−γ}} = α^{:.1}); \
         α* sits near the measured minimum. Note the truncation-bias integral assumes a pure\n\
         power-law beyond α, so small deviations appear where the body model matters.",
        3.0 - m.gamma
    );
    report.finish(&opts)?;
    Ok(())
}
